//! Fleet-engine scaling sweep: sats-simulated/sec and the peak-RSS
//! proxy (live machine count) at 10 → 100k satellites.
//!
//! Artifact-free by design: it steps [`tiansuan::sim::StubSat`]
//! machines — real [`Timeline`]s and the real sharded event scheduler,
//! synthetic capture/drain workload, no inference runtime — so CI can
//! always record the sweep.  The whole fleet runs in ONE process with
//! thread count = shard count (the thread-per-satellite driver this
//! engine replaces would need 2×N threads at these sizes).  Emits the
//! standard bench JSON (one object per line) that `ci.sh` greps into
//! `BENCH_fleet.json`.

use tiansuan::sim::{run_sharded, StubSat};
use tiansuan::util::bench;

fn main() {
    let shards = 8usize;
    let horizon_s = 21_600.0; // 6 h mission
    let scenes = 4usize;

    println!(
        "=== perf_fleet: sharded event scheduler, {shards} shards, \
         {scenes} scenes over {:.0} h ===",
        horizon_s / 3600.0
    );
    for n_sats in [10usize, 100, 1_000, 10_000, 100_000] {
        // uncapped (every machine live at once) vs the default
        // admission cap — same results, bounded peak footprint
        for cap in [0usize, 64] {
            let t0 = std::time::Instant::now();
            let (reports, stats) =
                run_sharded(n_sats, shards, cap, |id| Ok(StubSat::new(id, 42, scenes, horizon_s)))
                    .unwrap();
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(reports.len(), n_sats);
            let tiles: u64 = reports.iter().map(|r| r.tiles).sum();
            let wait = stats.admission_wait();
            println!(
                "fleet {n_sats:>7} sats cap {cap:>3}: {:>8.0} sats/s, \
                 {:>9} events ({:>9.0}/s), peak {:>7} live machines, \
                 heap≤{:>6}, admission wait p99 {:>9.1}s, {tiles} tiles",
                n_sats as f64 / wall.max(1e-12),
                stats.events,
                stats.events as f64 / wall.max(1e-12),
                stats.peak_live,
                stats.max_heap_depth,
                wait.p99_s,
            );
            bench::json_line(
                "perf_fleet.scaling",
                &[
                    ("sats", n_sats as f64),
                    ("shards", shards as f64),
                    ("max_events_in_flight", cap as f64),
                    ("wall_s", wall),
                    ("sats_per_s", n_sats as f64 / wall.max(1e-12)),
                    ("events", stats.events as f64),
                    ("events_per_s", stats.events as f64 / wall.max(1e-12)),
                    ("peak_live_machines", stats.peak_live as f64),
                    ("max_heap_depth", stats.max_heap_depth as f64),
                    ("admission_wait_mean_s", wait.mean_s),
                    ("admission_wait_p99_s", wait.p99_s),
                    ("tiles", tiles as f64),
                ],
            );
        }
    }

    // shard-count sweep at a fixed fleet: the parallelism dial's
    // throughput curve (results are invariant; only wall time moves)
    let n_sats = 10_000usize;
    for shards in [1usize, 2, 4, 8, 16] {
        let t0 = std::time::Instant::now();
        let (_, stats) =
            run_sharded(n_sats, shards, 64, |id| Ok(StubSat::new(id, 42, scenes, horizon_s)))
                .unwrap();
        let wall = t0.elapsed().as_secs_f64();
        // load balance across shards: sat_id % shards striping should
        // keep per-shard event counts within a few percent
        let ev_max = stats.events_per_shard.iter().copied().max().unwrap_or(0);
        let ev_min = stats.events_per_shard.iter().copied().min().unwrap_or(0);
        println!(
            "shards {shards:>2}: {n_sats} sats in {wall:.3} s ({:>8.0} sats/s, peak {} live, \
             shard events {ev_min}..{ev_max})",
            n_sats as f64 / wall.max(1e-12),
            stats.peak_live,
        );
        bench::json_line(
            "perf_fleet.shard_sweep",
            &[
                ("sats", n_sats as f64),
                ("shards", shards as f64),
                ("wall_s", wall),
                ("sats_per_s", n_sats as f64 / wall.max(1e-12)),
                ("peak_live_machines", stats.peak_live as f64),
                ("shard_events_min", ev_min as f64),
                ("shard_events_max", ev_max as f64),
            ],
        );
    }
}
