//! Perf: end-to-end pipeline throughput (tiles/s) — the headline serving
//! metric for the whole stack, per dataset version, plus a breakdown of
//! where the time goes (PJRT vs everything else).

use tiansuan::config::Config;
use tiansuan::coordinator::Pipeline;
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;
use tiansuan::util::bench;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.warmup()?;
    println!("=== perf: end-to-end pipeline (before/after batch-plan calibration) ===");
    for phase in ["baseline(pad-to-batch)", "calibrated(batch-plan)"] {
        if phase.starts_with("calibrated") {
            rt.calibrate()?; // L3 perf-pass change: cost-based batch plans
        }
        for version in [Version::V1, Version::V2] {
            let pipeline = Pipeline::new(&rt, Config::default());
            let (r, dt) = bench::once(&format!("pipeline/{}/{}", phase, version.name()), || {
                pipeline.run_scenario(version, 8).unwrap()
            });
            let wall = dt.as_secs_f64();
            let kept = r.tiles_total - r.tiles_filtered;
            println!(
                "{} {}: {} tiles ({} kept) in {:.2}s -> {:.1} tiles/s e2e; PJRT {:.2}s ({:.0}% of wall, {:.1} kept-tiles/s)",
                phase,
                r.version,
                r.tiles_total,
                kept,
                wall,
                r.tiles_total as f64 / wall,
                r.wall_infer_s,
                100.0 * r.wall_infer_s / wall,
                kept as f64 / r.wall_infer_s.max(1e-9),
            );
        }
    }
    Ok(())
}
