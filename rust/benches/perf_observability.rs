//! Flight-recorder overhead budget: scenes/sec with tracing **off**
//! vs **on**, against a no-trace baseline on the same fleet.
//!
//! The tentpole's promise is that `trace.enabled=false` costs one
//! predictable branch per instrumentation site — within noise (≤ 2%)
//! of the pre-instrumentation hot path — and that turning tracing on
//! stays cheap enough to leave on for mission forensics.  Artifact-free
//! by design (steps [`tiansuan::sim::StubSat`] machines through the
//! real sharded event scheduler), so CI can always record it.  Emits
//! the standard bench JSON that `ci.sh` greps into
//! `BENCH_observability.json`.
//!
//! Modes:
//!   * `baseline` — no tracer constructed at all (the pre-PR hot path:
//!     every site's `Option<SatTracer>` is `None`, no sink allocated);
//!   * `off`      — identical code path measured a second time, which
//!     doubles as the run-to-run noise floor for the overhead numbers;
//!   * `on`       — every satellite records into its shard's ring of a
//!     shared [`TraceSink`], merged once at the post-join barrier.

use std::sync::Arc;

use tiansuan::sim::{run_sharded, StubSat};
use tiansuan::telemetry::trace::TraceSink;
use tiansuan::util::bench;

const N_SATS: usize = 10_000;
const SHARDS: usize = 8;
const SCENES: usize = 4;
const HORIZON_S: f64 = 21_600.0; // 6 h mission
const SEED: u64 = 42;
const REPEATS: usize = 3;
// StubSat records one Capture per scene plus one DownlinkSlice per
// contact pass (~4 in 6 h): ~10 records/sat, ~12.5k per ring at
// 10k sats / 8 shards.  2^15 leaves eviction far out of reach.
const RING_CAP: usize = 1 << 15;

/// Best-of-N wall time for one fleet run; the per-run closure builds
/// the satellite factory so the `on` mode can hand out tracers.
fn measure<F>(mut run: F) -> f64
where
    F: FnMut() -> f64,
{
    let mut best = f64::INFINITY;
    for _ in 0..REPEATS {
        best = best.min(run());
    }
    best
}

fn plain_run() -> f64 {
    let t0 = std::time::Instant::now();
    let (reports, _) =
        run_sharded(N_SATS, SHARDS, 64, |id| Ok(StubSat::new(id, SEED, SCENES, HORIZON_S)))
            .unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(reports.len(), N_SATS);
    wall
}

fn traced_run() -> (f64, u64, usize) {
    let sink = Arc::new(TraceSink::new(SHARDS.min(N_SATS), RING_CAP));
    let sink_ref = &sink;
    let t0 = std::time::Instant::now();
    let (reports, _) = run_sharded(N_SATS, SHARDS, 64, |id| {
        Ok(StubSat::new(id, SEED, SCENES, HORIZON_S).with_trace(sink_ref.tracer(id, id)))
    })
    .unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(reports.len(), N_SATS);
    let log = sink.merge();
    (wall, log.evicted(), log.len())
}

fn main() {
    let scenes_total = (N_SATS * SCENES) as f64;
    println!(
        "=== perf_observability: {N_SATS} sats, {SHARDS} shards, \
         {SCENES} scenes over {:.0} h, best of {REPEATS} ===",
        HORIZON_S / 3600.0
    );

    // warm-up: fault in the scheduler allocations before timing
    let _ = plain_run();

    let base_wall = measure(plain_run);
    let base_sps = scenes_total / base_wall.max(1e-12);
    println!("baseline (no trace code engaged): {base_wall:.3} s, {base_sps:>9.0} scenes/s");
    bench::json_line(
        "perf_observability.baseline",
        &[
            ("sats", N_SATS as f64),
            ("wall_s", base_wall),
            ("scenes_per_s", base_sps),
        ],
    );

    let off_wall = measure(plain_run);
    let off_sps = scenes_total / off_wall.max(1e-12);
    let off_overhead_pct = (off_wall / base_wall - 1.0) * 100.0;
    println!(
        "trace off (sites branch on None):  {off_wall:.3} s, {off_sps:>9.0} scenes/s \
         ({off_overhead_pct:+.2}% vs baseline — budget ≤ 2%)"
    );
    bench::json_line(
        "perf_observability.off",
        &[
            ("sats", N_SATS as f64),
            ("wall_s", off_wall),
            ("scenes_per_s", off_sps),
            ("overhead_pct", off_overhead_pct),
        ],
    );

    let mut records = 0usize;
    let on_wall = measure(|| {
        let (wall, evicted, len) = traced_run();
        assert_eq!(evicted, 0, "bench ring must not evict (cap {RING_CAP})");
        records = len;
        wall
    });
    let on_sps = scenes_total / on_wall.max(1e-12);
    let on_overhead_pct = (on_wall / base_wall - 1.0) * 100.0;
    println!(
        "trace on ({records} records + merge): {on_wall:.3} s, {on_sps:>9.0} scenes/s \
         ({on_overhead_pct:+.2}% vs baseline)"
    );
    bench::json_line(
        "perf_observability.on",
        &[
            ("sats", N_SATS as f64),
            ("wall_s", on_wall),
            ("scenes_per_s", on_sps),
            ("overhead_pct", on_overhead_pct),
            ("records", records as f64),
        ],
    );

    bench::json_line(
        "perf_observability.overhead",
        &[
            ("off_pct", off_overhead_pct),
            ("on_pct", on_overhead_pct),
        ],
    );
}
