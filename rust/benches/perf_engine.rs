//! Perf: sequential vs staged-concurrent scenario throughput (tiles/s).
//!
//! The staged engine overlaps capture, onboard (CloudScore + TinyDet)
//! and ground (HeavyDet) inference across scenes; with enough workers it
//! must beat the sequential facade while producing bit-identical
//! results.  Emits the standard bench JSON (one object per line) so
//! EXPERIMENTS tooling can diff runs.

use tiansuan::config::Config;
use tiansuan::coordinator::{Pipeline, StagedEngine};
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;
use tiansuan::util::bench;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.warmup()?;
    rt.calibrate()?;
    let scenes = 6;
    println!("=== perf: staged engine vs sequential facade ({scenes} scenes) ===");
    for version in [Version::V1, Version::V2] {
        let cfg = Config::default();
        let pipeline = Pipeline::new(&rt, cfg.clone());
        let (seq, seq_dt) =
            bench::once(&format!("engine/{}/sequential", version.name()), || {
                pipeline.run_scenario(version, scenes).unwrap()
            });
        let seq_tps = seq.tiles_total as f64 / seq_dt.as_secs_f64();
        bench::json_line(
            &format!("perf_engine.{}.sequential", version.name()),
            &[
                ("tiles", seq.tiles_total as f64),
                ("wall_s", seq_dt.as_secs_f64()),
                ("tiles_per_s", seq_tps),
            ],
        );

        for workers in [1usize, 2, 4] {
            let engine = StagedEngine::new(&pipeline).with_workers(workers);
            let (r, dt) = bench::once(
                &format!("engine/{}/staged/w{workers}", version.name()),
                || engine.run_scenario(version, scenes).unwrap(),
            );
            // staged results must be identical, not merely similar
            assert_eq!(r.tiles_total, seq.tiles_total, "tile mismatch at w{workers}");
            assert_eq!(r.map_collab, seq.map_collab, "mAP mismatch at w{workers}");
            let tps = r.tiles_total as f64 / dt.as_secs_f64();
            bench::json_line(
                &format!("perf_engine.{}.staged", version.name()),
                &[
                    ("workers", workers as f64),
                    ("tiles", r.tiles_total as f64),
                    ("wall_s", dt.as_secs_f64()),
                    ("tiles_per_s", tps),
                    ("speedup_vs_sequential", tps / seq_tps),
                ],
            );
        }
    }
    Ok(())
}
