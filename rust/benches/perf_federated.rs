//! Perf + scenario: battery-capacity sweep for power-aware federated
//! scheduling — how many training rounds a fleet completes, and what
//! global accuracy it reaches, as the battery grows.
//!
//! Artifact-free by design: four workers fly the governed federated
//! profile ([`tiansuan::power::fly_federated_mission`]) over a real
//! eclipse-heavy orbital timeline, then the recorded participant sets
//! are replayed with partial-participation FedAvg — no inference
//! runtime involved, so CI can always record the sweep.  Emits the
//! standard bench JSON (one object per line) that `ci.sh` greps into
//! `BENCH_federated.json`.

use tiansuan::config::{EnergyConfig, FederatedConfig, PowerConfig, TimingConfig};
use tiansuan::orbit::{baoyun, beijing_station};
use tiansuan::power::{fly_federated_mission, PowerState};
use tiansuan::sedna::federated::{self, FedScheduler};
use tiansuan::sim::{DutyCycles, Timeline};
use tiansuan::util::bench;

fn main() {
    let sat = baoyun();
    let horizon = 6.0 * sat.period_s(); // six revolutions, ~38% eclipse each
    let period_s = 30.0;
    let timeline =
        Timeline::orbital(&TimingConfig::default(), &sat, &beijing_station(), horizon, 10.0);
    let active = DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 };
    let energy = EnergyConfig { pi_idle_floor: 0.0, comm_idle_floor: 0.0 };
    let fed = FederatedConfig {
        enabled: true,
        round_interval_s: 600.0,
        min_soc: 0.5,
        ..FederatedConfig::default()
    };
    let workers = 4usize;
    let train_s = federated::train_seconds(fed.epochs, fed.samples_per_node);
    let rounds = FedScheduler::rounds_in(horizon, fed.round_interval_s);
    let shards = federated::fleet_shards(workers, fed.samples_per_node, fed.dim, 7);
    let test = federated::make_shard(7 + 10_000, 2000, fed.dim, 0.0);

    println!(
        "=== perf_federated: battery sweep, {workers} workers x {rounds} rounds over {:.1} h ({:.0}% sunlit) ===",
        horizon / 3600.0,
        100.0 * timeline.sunlit_fraction(0.0, horizon)
    );
    for battery_wh in [20.0, 40.0, 60.0, 80.0, 120.0, 240.0] {
        let mut scheds: Vec<FedScheduler> = Vec::with_capacity(workers);
        for w in 0..workers {
            let power = PowerConfig {
                enabled: true,
                battery_wh,
                panel_w: 95.0,
                cosine_derate: 0.8,
                // stagger initial charge so the participant set differs
                // per worker and partial-participation FedAvg is exercised
                initial_soc: 0.3 + 0.15 * w as f64,
                soc_defer: 0.6,
                soc_critical: 0.3,
                ..PowerConfig::default()
            };
            let mut state = PowerState::new(&power, &energy);
            let mut sched = FedScheduler::new(&fed, horizon);
            fly_federated_mission(&mut state, &mut sched, &timeline, active, period_s, train_s);
            scheds.push(sched);
        }
        let t0 = std::time::Instant::now();
        let rep = federated::train_schedule(
            &shards,
            &test,
            rounds,
            |r, w| scheds[w].stats.participated[r],
            fed.epochs,
            fed.lr,
            fed.dim,
            7,
        );
        let wall = t0.elapsed().as_secs_f64();
        let completed: u64 = scheds.iter().map(|s| s.stats.rounds_completed).sum();
        let skipped: u64 = scheds.iter().map(|s| s.stats.rounds_skipped_power).sum();
        println!(
            "battery {battery_wh:>5.0} Wh: {completed:>3} rounds trained / {skipped:>3} skipped for power \
             (fleet of {}), final accuracy {:.3}, {} held rounds, {} B weights",
            workers * rounds,
            rep.final_accuracy(),
            rep.rounds_held,
            rep.uplink_bytes,
        );
        bench::json_line(
            "perf_federated.battery_sweep",
            &[
                ("battery_wh", battery_wh),
                ("rounds_scheduled", (workers * rounds) as f64),
                ("rounds_completed", completed as f64),
                ("rounds_skipped_power", skipped as f64),
                ("rounds_held", rep.rounds_held as f64),
                ("final_accuracy", rep.final_accuracy()),
                ("uplink_bytes", rep.uplink_bytes as f64),
                ("train_wall_s", wall),
            ],
        );
    }

    // hot loop: per-mission cost of SoC integration + round scheduling
    // (what the constellation driver pays per satellite when enabled)
    let power = PowerConfig { enabled: true, ..PowerConfig::default() };
    let stats = bench::run(
        "federated/schedule/6rev",
        10,
        std::time::Duration::from_millis(500),
        || {
            let mut state = PowerState::new(&power, &energy);
            let mut sched = FedScheduler::new(&fed, horizon);
            fly_federated_mission(&mut state, &mut sched, &timeline, active, period_s, train_s);
            std::hint::black_box(sched.stats.rounds_completed);
        },
    );
    bench::json_line(
        "perf_federated.schedule",
        &[
            ("rounds", rounds as f64),
            ("median_s", stats.median.as_secs_f64()),
            ("rounds_per_s", rounds as f64 / stats.median.as_secs_f64().max(1e-12)),
        ],
    );
}
