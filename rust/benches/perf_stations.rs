//! Station-count sweep: what does adding ground stations buy a 1k-sat
//! plane over one day?
//!
//! For 1, 3 and 8 stations this measures, per configuration:
//!
//! * contact minutes per satellite per day on the *scheduled* (disjoint,
//!   one-transmitter) track,
//! * bytes actually delivered by draining a fixed per-satellite backlog
//!   through the ARQ link over the scheduled windows, against the best
//!   any single station of the set manages alone,
//! * scheduler planning throughput (strategy decisions per second).
//!
//! The byte drain uses a deliberately constrained 1 Mbps transmitter so
//! airtime — not the sensor — is the binding resource and the packet-level
//! ARQ sim stays at ~10^7 packets.  Ratios across station counts are what
//! matter and those are rate-independent.  ci.sh records the
//! `{"bench":...}` lines into BENCH_stations.json; the multi-vs-best-single
//! comparison is the PR's acceptance criterion and is asserted here.

use std::time::Duration;

use tiansuan::config::{Config, StationConfig};
use tiansuan::coordinator::downlink::{DownlinkItem, DownlinkQueue, ItemKind};
use tiansuan::coordinator::{
    plane_satellite, station_network, ContactScheduler, SchedulerStats, CONTACT_SCAN_STEP_S,
};
use tiansuan::link::{Link, LinkConfig, LossProfile};
use tiansuan::orbit::ContactWindow;
use tiansuan::util::bench;

const DAY_S: f64 = 86_400.0;
const SATS: usize = 1000;
/// Satellites whose backlog is actually drained through the packet-level
/// link sim (every `SATS / DRAIN_SATS`-th plane slot).  Draining all 1k
/// would simulate ~10^9 packets for no extra signal; the subsample is
/// printed so the cap is never silent.
const DRAIN_SATS: usize = 25;

fn station(name: &str, lat_deg: f64, lon_deg: f64) -> StationConfig {
    StationConfig { name: name.to_string(), lat_deg, lon_deg, min_elevation_deg: 10.0 }
}

/// First `n` of a fixed global roster.  Index 0 is the paper's Beijing
/// station (the config default); 3 stations is the Beijing/Kashi/Sanya
/// domestic triangle; 8 adds a commercial polar-and-southern spread.
fn station_set(n: usize) -> Vec<StationConfig> {
    let roster = vec![
        StationConfig::default(), // Beijing
        station("Kashi", 39.47, 75.98),
        station("Sanya", 18.23, 109.50),
        station("Kiruna", 67.86, 20.96),
        station("Svalbard", 78.23, 15.39),
        station("Perth", -31.80, 115.89),
        station("Santiago", -33.13, -70.67),
        station("Fairbanks", 64.80, -147.50),
    ];
    assert!(n <= roster.len());
    roster.into_iter().take(n).collect()
}

fn sweep_config(n_stations: usize) -> Config {
    let mut cfg = Config::default();
    cfg.constellation.satellites = SATS;
    cfg.constellation.horizon_s = DAY_S;
    cfg.stations = station_set(n_stations);
    cfg
}

/// Constrained transmitter for the byte drain (see module doc).
fn drain_link() -> LinkConfig {
    LinkConfig { rate_bps: 1e6, mtu: 1400, loss: LossProfile::stable(), max_tries: 8 }
}

/// One day of observations: a 1 MB image every 2 minutes (720 MB), about
/// 2x what one station's daily airtime carries at the drain-link rate —
/// so extra stations turn directly into extra delivered bytes.
fn day_backlog() -> Vec<DownlinkItem> {
    (0..720)
        .map(|i| DownlinkItem {
            kind: ItemKind::Image,
            bytes: 1_000_000,
            ready_at: i as f64 * 120.0,
            tag: i,
        })
        .collect()
}

/// Drain the standard backlog over `windows`; returns total delivered
/// bytes.  `seed` keeps the Gilbert–Elliott chain deterministic per
/// satellite while decorrelating satellites.
fn drained_bytes(windows: &[ContactWindow], seed: u64) -> u64 {
    let mut queue = DownlinkQueue::new();
    for item in day_backlog() {
        queue.push(item);
    }
    let mut link = Link::new(drain_link(), seed);
    for w in windows {
        queue.drain_window(&mut link, w);
    }
    queue.stats.total_bytes()
}

struct SweepRow {
    stations: usize,
    contact_min_per_sat: f64,
    scheduled_bytes: u64,
    best_single_bytes: u64,
    decisions_per_s: f64,
    fleet: SchedulerStats,
}

fn sweep(n_stations: usize) -> SweepRow {
    let cfg = sweep_config(n_stations);
    let net = station_network(&cfg);
    let scheduler = ContactScheduler::greedy();

    let mut all_tracks = Vec::with_capacity(SATS);
    let mut fleet = SchedulerStats::default();
    let mut scheduled_s = 0.0;
    let mut scheduled_bytes = 0u64;
    let mut single_bytes = vec![0u64; n_stations];
    let drain_stride = SATS / DRAIN_SATS;

    for i in 0..SATS {
        let sat = plane_satellite(&cfg, i, &format!("bench-{i}"));
        let tracks = net.contact_tracks(&sat, 0.0, DAY_S, CONTACT_SCAN_STEP_S);
        let (plan, stats) = scheduler.plan(&tracks);
        scheduled_s += plan.iter().map(ContactWindow::duration_s).sum::<f64>();
        fleet.absorb(&stats);
        if i % drain_stride == 0 {
            scheduled_bytes += drained_bytes(&plan, i as u64);
            // each station alone, same backlog and seed: its raw track is
            // exactly what a single-station mission over that site sees
            for (s, track) in tracks.iter().enumerate() {
                single_bytes[s] += drained_bytes(track, i as u64);
            }
        }
        all_tracks.push(tracks);
    }

    let decisions_per_replan = fleet.decisions;
    let timed = bench::run(
        &format!("perf_stations.plan_{n_stations}st_{SATS}sat"),
        3,
        Duration::from_millis(300),
        || {
            for tracks in &all_tracks {
                std::hint::black_box(scheduler.plan(tracks));
            }
        },
    );
    let decisions_per_s = decisions_per_replan as f64 / timed.median.as_secs_f64();

    SweepRow {
        stations: n_stations,
        contact_min_per_sat: scheduled_s / 60.0 / SATS as f64,
        scheduled_bytes,
        best_single_bytes: single_bytes.iter().copied().max().unwrap_or(0),
        decisions_per_s,
        fleet,
    }
}

fn main() {
    println!(
        "perf_stations: {SATS} satellites, 1-day horizon, \
         byte drain over {DRAIN_SATS} sampled satellites at 1 Mbps"
    );
    let mut rows = Vec::new();
    for n in [1usize, 3, 8] {
        let row = sweep(n);
        println!(
            "{} station(s): {:.1} contact min/sat/day  \
             delivered {:.1} MB (best single station {:.1} MB)  \
             {:.0} decisions/s  clipped {} shadowed {}",
            row.stations,
            row.contact_min_per_sat,
            row.scheduled_bytes as f64 / 1e6,
            row.best_single_bytes as f64 / 1e6,
            row.decisions_per_s,
            row.fleet.clipped,
            row.fleet.shadowed,
        );
        bench::json_line(
            "perf_stations.sweep",
            &[
                ("stations", row.stations as f64),
                ("sats", SATS as f64),
                ("drain_sats", DRAIN_SATS as f64),
                ("contact_min_per_sat_day", row.contact_min_per_sat),
                ("bytes_delivered", row.scheduled_bytes as f64),
                ("best_single_station_bytes", row.best_single_bytes as f64),
                ("decisions_per_s", row.decisions_per_s),
                ("clipped", row.fleet.clipped as f64),
                ("shadowed", row.fleet.shadowed as f64),
            ],
        );
        rows.push(row);
    }

    // Acceptance: any >= 2-station network must deliver strictly more
    // bytes than the best single station of its set manages alone.
    for row in rows.iter().filter(|r| r.stations >= 2) {
        assert!(
            row.scheduled_bytes > row.best_single_bytes,
            "{} stations delivered {} bytes, not more than best single station's {}",
            row.stations,
            row.scheduled_bytes,
            row.best_single_bytes
        );
    }
    // More stations never shrink the scheduled contact plane.
    for pair in rows.windows(2) {
        assert!(
            pair[1].contact_min_per_sat >= pair[0].contact_min_per_sat,
            "contact minutes fell from {} to {} stations",
            pair[0].stations,
            pair[1].stations
        );
    }
    println!("perf_stations: multi-station yield exceeds best single station — ok");
}
