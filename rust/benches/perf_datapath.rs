//! Perf: the zero-copy hot data path — pooled row-sliced tiling +
//! scratch-batched marshalling vs the retained pre-refactor naive path.
//!
//! Artifact-free by design (no PJRT runtime): the measured work is the
//! data movement *around* the model — cut, batch, gather, tail pad —
//! which is exactly what the zero-copy PR rebuilt.  The naive reference
//! (`naive_split` + `naive_marshal`) is the seed implementation kept
//! verbatim for comparison; the acceptance bar is ≥2× tiles/sec on the
//! combined tiling+marshalling flow.  A stub-runtime `onboard_scene`
//! loop (split → cloud-filter stub → batcher → gather → decode → NMS →
//! route) reports scenes/sec and the pool hit rate.  Emits the standard
//! bench JSON that `ci.sh` greps into `BENCH_datapath.json`.

use std::hint::black_box;
use std::time::Duration;

use tiansuan::coordinator::batcher::Batcher;
use tiansuan::coordinator::cloudfilter::{
    is_redundant_f32, is_redundant_quant, quant_threshold, quantize_pixels, white_count_quant,
    white_frac_f32,
};
use tiansuan::coordinator::router::{route, RouterPolicy, RouterStats};
use tiansuan::data::{
    gather_pixels, reference_cut, split_scene_pooled, Scene, SceneGen, Tile, Version, MODEL_TILE,
    TILE_PX,
};
use tiansuan::detect::{decode_rows, nms};
use tiansuan::util::bench;
use tiansuan::util::buffer::{PixelPool, QuantPool};
use tiansuan::util::rng::Rng;

/// Largest exported artifact batch (manifest.batch_sizes max in the
/// real runtime) — the marshalling chunk size.
const MAX_BATCH: usize = 8;

/// The seed split: [`reference_cut`] (the frozen pre-refactor per-pixel
/// implementation, shared with `tests/datapath_golden.rs` so the perf
/// baseline and the correctness golden can never diverge) over the
/// fragment grid, fresh 48 KB Vec + GT rescale per tile.
fn naive_split(scene: &Scene, frag: usize) -> Vec<Vec<f32>> {
    let mut tiles = Vec::with_capacity((scene.width / frag) * (scene.height / frag));
    for y0 in (0..scene.height).step_by(frag) {
        for x0 in (0..scene.width).step_by(frag) {
            let (pixels, gt) = reference_cut(scene, x0, y0, frag);
            black_box(gt); // the pooled path builds GT too — keep it fair
            tiles.push(pixels);
        }
    }
    tiles
}

/// The seed marshal: per-chunk concat Vec, tail re-copied + resized —
/// the `infer` + `execute` allocation chain before the scratch pool.
fn naive_marshal(tiles: &[Vec<f32>]) -> f32 {
    let mut acc = 0.0f32;
    for chunk in tiles.chunks(MAX_BATCH) {
        let mut input = Vec::with_capacity(chunk.len() * TILE_PX);
        for t in chunk {
            input.extend_from_slice(t);
        }
        if chunk.len() < MAX_BATCH {
            let mut padded = input.to_vec();
            padded.resize(MAX_BATCH * TILE_PX, 0.0);
            acc += padded[0] + padded[MAX_BATCH * TILE_PX - 1];
        } else {
            acc += input[0];
        }
    }
    acc
}

/// Tiles marshalled per scene: all but the last 3, so every frag size
/// ends on a ragged tail and both paths pay their padding step.
fn marshal_count(n_tiles: usize) -> usize {
    n_tiles - 3
}

/// The zero-copy flow: pooled row-sliced split, gather into pooled
/// dirty scratch, ragged tail padded in place (only the pad rows are
/// zeroed) — the same steps `infer` + `execute` take.
fn pooled_flow(scene: &Scene, frag: usize, tiles: &PixelPool, marshal: &PixelPool) -> f32 {
    let split = split_scene_pooled(scene, frag, tiles);
    let batch = &split[..marshal_count(split.len())];
    let mut scratch = marshal.checkout_dirty();
    let mut acc = 0.0f32;
    for chunk in batch.chunks(MAX_BATCH) {
        let n = gather_pixels(chunk, &mut scratch);
        if chunk.len() < MAX_BATCH {
            let mut padded = marshal.checkout_dirty();
            padded[..n].copy_from_slice(&scratch[..n]);
            padded[n..MAX_BATCH * TILE_PX].fill(0.0);
            acc += padded[0] + padded[MAX_BATCH * TILE_PX - 1];
        } else {
            acc += scratch[0];
        }
    }
    acc
}

fn main() {
    let scene = SceneGen::new(7, Version::V2.spec(), 8, 8).capture(); // 512x512
    let tile_pool = PixelPool::new(TILE_PX);
    let marshal_pool = PixelPool::new(MAX_BATCH * TILE_PX);

    println!("=== perf_datapath: pooled row-sliced tiling + scratch marshalling vs naive ===");
    let mut naive_total_s = 0.0;
    let mut pooled_total_s = 0.0;
    let mut total_tiles = 0.0;
    for frag in [32usize, 64, 128] {
        let n_tiles = ((scene.width / frag) * (scene.height / frag)) as f64;
        let naive = bench::run(
            &format!("datapath/naive/frag{frag}"),
            10,
            Duration::from_millis(300),
            || {
                let tiles = naive_split(&scene, frag);
                black_box(naive_marshal(&tiles[..marshal_count(tiles.len())]));
            },
        );
        let pooled = bench::run(
            &format!("datapath/pooled/frag{frag}"),
            10,
            Duration::from_millis(300),
            || {
                black_box(pooled_flow(&scene, frag, &tile_pool, &marshal_pool));
            },
        );
        let naive_tps = n_tiles / naive.median.as_secs_f64();
        let pooled_tps = n_tiles / pooled.median.as_secs_f64();
        bench::json_line(
            "perf_datapath.tile_marshal",
            &[
                ("frag", frag as f64),
                ("tiles", n_tiles),
                ("naive_tiles_per_s", naive_tps),
                ("pooled_tiles_per_s", pooled_tps),
                ("speedup", pooled_tps / naive_tps),
            ],
        );
        naive_total_s += naive.median.as_secs_f64();
        pooled_total_s += pooled.median.as_secs_f64();
        total_tiles += n_tiles;
    }
    let stats = tile_pool.stats();
    let agg_naive = total_tiles / naive_total_s;
    let agg_pooled = total_tiles / pooled_total_s;
    println!(
        "datapath aggregate: naive {agg_naive:.0} tiles/s, pooled {agg_pooled:.0} tiles/s \
         ({:.2}x), tile-pool hit rate {:.1}% ({} allocs / {} checkouts)",
        agg_pooled / agg_naive,
        100.0 * stats.hit_rate(),
        stats.allocs,
        stats.checkouts,
    );
    bench::json_line(
        "perf_datapath.tile_marshal_total",
        &[
            ("naive_tiles_per_s", agg_naive),
            ("pooled_tiles_per_s", agg_pooled),
            ("speedup", agg_pooled / agg_naive),
            ("pool_hit_rate", stats.hit_rate()),
            ("pool_allocs", stats.allocs as f64),
        ],
    );

    // ---- per-kernel: frozen scalar reference vs vectorized lane kernels ----
    // `naive_split` IS `reference_cut` — the frozen per-pixel scalar —
    // while `split_scene_pooled` runs the channel-lane kernels (lane-array
    // box filter, wide-copy upsample/identity) over pooled buffers.
    // Byte-for-byte equality is pinned in tests/datapath_golden.rs; this
    // section measures what the lane rewrite buys per kernel shape (deep
    // upsample 16→64 through deep box filter 256→64).
    println!("=== perf_datapath: scalar reference vs vectorized tile kernels ===");
    for frag in [16usize, 32, 64, 128, 256] {
        let n_tiles = ((scene.width / frag) * (scene.height / frag)) as f64;
        let scalar = bench::run(
            &format!("datapath/kernel_scalar/frag{frag}"),
            10,
            Duration::from_millis(300),
            || {
                black_box(naive_split(&scene, frag));
            },
        );
        let simd = bench::run(
            &format!("datapath/kernel_simd/frag{frag}"),
            10,
            Duration::from_millis(300),
            || {
                black_box(split_scene_pooled(&scene, frag, &tile_pool));
            },
        );
        let scalar_tps = n_tiles / scalar.median.as_secs_f64();
        let simd_tps = n_tiles / simd.median.as_secs_f64();
        bench::json_line(
            "perf_datapath.kernels",
            &[
                ("frag", frag as f64),
                ("tiles", n_tiles),
                ("scalar_tiles_per_s", scalar_tps),
                ("simd_tiles_per_s", simd_tps),
                ("speedup", simd_tps / scalar_tps),
            ],
        );
    }

    // ---- f32 vs i8 cloud-filter scoring over one scene's tiles ----
    // Decisions use the CloudScore kernel's white threshold (0.72) and
    // the manifest's redundancy threshold (0.5); mismatches (tiles the
    // two paths partition differently — legal only inside the 1/127
    // quantization band, see tests/datapath_golden.rs) are reported
    // alongside the throughputs.
    const KERNEL_WHITE: f32 = 0.72;
    const REDUNDANT_FRAC: f32 = 0.5;
    let filter_tiles = split_scene_pooled(&scene, 64, &tile_pool);
    let quant_pool = QuantPool::new(TILE_PX);
    let f32_run = bench::run(
        "datapath/filter_f32",
        10,
        Duration::from_millis(300),
        || {
            let mut dropped = 0usize;
            for t in &filter_tiles {
                if is_redundant_f32(white_frac_f32(&t.pixels, KERNEL_WHITE), REDUNDANT_FRAC) {
                    dropped += 1;
                }
            }
            black_box(dropped);
        },
    );
    let i8_run = bench::run(
        "datapath/filter_i8",
        10,
        Duration::from_millis(300),
        || {
            let qthr = quant_threshold(KERNEL_WHITE);
            let mut scratch = quant_pool.checkout_dirty();
            let mut dropped = 0usize;
            for t in &filter_tiles {
                let q = &mut scratch[..t.pixels.len()];
                quantize_pixels(&t.pixels, q);
                let white = white_count_quant(q, qthr);
                if is_redundant_quant(white, t.pixels.len() / 3, REDUNDANT_FRAC) {
                    dropped += 1;
                }
            }
            black_box(dropped);
        },
    );
    // decision-agreement audit, outside the timed loops
    let mut mismatches = 0usize;
    {
        let qthr = quant_threshold(KERNEL_WHITE);
        let mut scratch = quant_pool.checkout_dirty();
        for t in &filter_tiles {
            let f = is_redundant_f32(white_frac_f32(&t.pixels, KERNEL_WHITE), REDUNDANT_FRAC);
            let q = &mut scratch[..t.pixels.len()];
            quantize_pixels(&t.pixels, q);
            let i =
                is_redundant_quant(white_count_quant(q, qthr), t.pixels.len() / 3, REDUNDANT_FRAC);
            if f != i {
                mismatches += 1;
            }
        }
    }
    let n_filter_tiles = filter_tiles.len() as f64;
    let f32_tps = n_filter_tiles / f32_run.median.as_secs_f64();
    let i8_tps = n_filter_tiles / i8_run.median.as_secs_f64();
    println!(
        "filter: f32 {f32_tps:.0} tiles/s, i8 {i8_tps:.0} tiles/s ({:.2}x), \
         {mismatches} decision mismatches over {} tiles",
        i8_tps / f32_tps,
        filter_tiles.len(),
    );
    bench::json_line(
        "perf_datapath.filter",
        &[
            ("tiles", n_filter_tiles),
            ("f32_tiles_per_s", f32_tps),
            ("i8_tiles_per_s", i8_tps),
            ("speedup", i8_tps / f32_tps),
            ("decision_mismatches", mismatches as f64),
        ],
    );
    drop(filter_tiles);

    // ---- scenes/sec through the onboard hot loop with a stub runtime ----
    // Split → cloud-filter stub (the CloudScore white-fraction statistic
    // recomputed in rust) → batcher → gather → decode → NMS → route: the
    // full onboard data movement with inference stubbed by synthetic
    // model rows, so the bench isolates the coordinator's share.
    let (grid, head_d) = (8usize, 13usize);
    let cols = grid * grid * head_d;
    let mut rng = Rng::new(3);
    let rows: Vec<f32> = (0..MAX_BATCH * cols).map(|_| rng.f32()).collect();
    let policy = RouterPolicy::default();
    let pool = PixelPool::new(TILE_PX);
    let scratch_pool = PixelPool::new(MAX_BATCH * TILE_PX);
    let mut gen = SceneGen::new(21, Version::V2.spec(), 8, 8);
    let scene = gen.capture();
    let tiles_per_scene = (scene.width / 64) * (scene.height / 64);
    let onboard = bench::run(
        "datapath/onboard_scene_stub",
        5,
        Duration::from_millis(500),
        || {
            let split = split_scene_pooled(&scene, 64, &pool);
            // cloud-filter stub: white fraction > 0.6 ⇒ redundant
            let kept: Vec<Tile> = split
                .into_iter()
                .filter(|t| {
                    let white = t
                        .pixels
                        .chunks_exact(3)
                        .filter(|p| p[0].min(p[1]).min(p[2]) > 0.82)
                        .count();
                    (white as f32) < 0.6 * (MODEL_TILE * MODEL_TILE) as f32
                })
                .collect();
            let mut batcher = Batcher::new(MAX_BATCH, 0.05);
            for t in kept {
                batcher.push(t, 0.0);
            }
            let mut stats = RouterStats::default();
            let mut delays = Vec::with_capacity(MAX_BATCH);
            let mut scratch = scratch_pool.checkout_dirty();
            while let Some(batch) = batcher.pop(0.0, true, &mut delays) {
                let n = gather_pixels(&batch, &mut scratch);
                black_box(&scratch[..n]); // stub: the PJRT literal copy
                for (i, t) in batch.iter().enumerate() {
                    let r = &rows[i * cols..(i + 1) * cols];
                    let dets = nms(decode_rows(r, head_d, 0.25), 0.45);
                    let best = r.chunks_exact(head_d).map(|c| c[4]).fold(f32::MIN, f32::max);
                    black_box(route(&policy, &dets, best, &mut stats));
                    black_box(t.scene_id);
                }
            }
        },
    );
    let s = pool.stats();
    bench::json_line(
        "perf_datapath.onboard_stub",
        &[
            ("scenes_per_s", 1.0 / onboard.median.as_secs_f64()),
            ("tiles_per_scene", tiles_per_scene as f64),
            (
                "tiles_per_s",
                tiles_per_scene as f64 / onboard.median.as_secs_f64(),
            ),
            ("pool_hit_rate", s.hit_rate()),
            ("pool_allocs", s.allocs as f64),
        ],
    );

    // ---- the same stub loop with the quantized cloud filter ----
    // Identical decision rule (0.6·4096 = 2457.6: `white < 2457.6` ⟺
    // `white <= 2457 = floor(0.6·n)`), but the whiteness statistic comes
    // from pooled-i8 quantize + integer count instead of the f32 sweep —
    // the `policy.filter_precision = "i8"` hot loop, scenes/sec headline.
    let stub_quant = QuantPool::new(TILE_PX);
    let onboard_i8 = bench::run(
        "datapath/onboard_scene_stub_i8",
        5,
        Duration::from_millis(500),
        || {
            let split = split_scene_pooled(&scene, 64, &pool);
            let qthr = quant_threshold(0.82);
            let mut qscratch = stub_quant.checkout_dirty();
            let kept: Vec<Tile> = split
                .into_iter()
                .filter(|t| {
                    let q = &mut qscratch[..t.pixels.len()];
                    quantize_pixels(&t.pixels, q);
                    let white = white_count_quant(q, qthr);
                    !is_redundant_quant(white, t.pixels.len() / 3, 0.6)
                })
                .collect();
            let mut batcher = Batcher::new(MAX_BATCH, 0.05);
            for t in kept {
                batcher.push(t, 0.0);
            }
            let mut stats = RouterStats::default();
            let mut delays = Vec::with_capacity(MAX_BATCH);
            let mut scratch = scratch_pool.checkout_dirty();
            while let Some(batch) = batcher.pop(0.0, true, &mut delays) {
                let n = gather_pixels(&batch, &mut scratch);
                black_box(&scratch[..n]);
                for (i, t) in batch.iter().enumerate() {
                    let r = &rows[i * cols..(i + 1) * cols];
                    let dets = nms(decode_rows(r, head_d, 0.25), 0.45);
                    let best = r.chunks_exact(head_d).map(|c| c[4]).fold(f32::MIN, f32::max);
                    black_box(route(&policy, &dets, best, &mut stats));
                    black_box(t.scene_id);
                }
            }
        },
    );
    let f32_scenes = 1.0 / onboard.median.as_secs_f64();
    let i8_scenes = 1.0 / onboard_i8.median.as_secs_f64();
    println!(
        "onboard stub: f32 filter {f32_scenes:.1} scenes/s, i8 filter {i8_scenes:.1} scenes/s \
         ({:.2}x)",
        i8_scenes / f32_scenes,
    );
    bench::json_line(
        "perf_datapath.onboard_stub_i8",
        &[
            ("scenes_per_s", i8_scenes),
            ("tiles_per_scene", tiles_per_scene as f64),
            ("tiles_per_s", tiles_per_scene as f64 * i8_scenes),
            ("speedup_vs_f32", i8_scenes / f32_scenes),
        ],
    );
}
