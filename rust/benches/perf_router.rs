//! Perf: L3 hot-path pieces that run per tile — decode, NMS, routing,
//! scene split, and the cloud-score threshold.  The coordinator must
//! never be the bottleneck relative to PJRT inference (DESIGN.md §Perf).

use std::time::Duration;

use tiansuan::config::Config;
use tiansuan::coordinator::router::{route, RouterPolicy, RouterStats};
use tiansuan::data::{split_scene, SceneGen, Version};
use tiansuan::detect::{decode_rows, nms};
use tiansuan::util::bench;
use tiansuan::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let head_d = 13;
    let rows: Vec<f32> = (0..64 * head_d).map(|_| rng.f32()).collect();

    bench::run("router/decode_rows_64cells", 100, Duration::from_millis(400), || {
        std::hint::black_box(decode_rows(&rows, head_d, 0.2));
    });

    let dets = decode_rows(&rows, head_d, 0.01); // dense: worst case for NMS
    println!("  (nms input: {} detections)", dets.len());
    bench::run("router/nms_dense", 100, Duration::from_millis(400), || {
        std::hint::black_box(nms(dets.clone(), 0.45));
    });

    let policy = RouterPolicy::default();
    let kept = nms(dets.clone(), 0.45);
    bench::run("router/route", 100, Duration::from_millis(200), || {
        let mut stats = RouterStats::default();
        std::hint::black_box(route(&policy, &kept, 0.7, &mut stats));
    });

    let cfg = Config::default();
    let scene = SceneGen::new(cfg.seed, Version::V2.spec(), 8, 8).capture();
    bench::run("router/split_scene_512px_frag64", 20, Duration::from_millis(600), || {
        std::hint::black_box(split_scene(&scene, 64));
    });
    bench::run("router/scene_capture_512px", 5, Duration::from_millis(800), || {
        let mut g = SceneGen::new(1, Version::V2.spec(), 8, 8);
        std::hint::black_box(g.capture());
    });
}
