//! Perf: the chaos fault-injection layer — what deterministic fault
//! plans and the ARQ retry loop cost on the downlink hot path.
//!
//! Artifact-free by design: the measured work is plan compilation
//! (Poisson window scheduling) and windowed backlog drains through
//! `drain_window_sliced_chaos` at three fault intensities (0%, 1%, 10%
//! per-transfer frame-fault probability, with crash/dropout rates
//! scaled alongside) over a 1 000-satellite sweep.  Before timing
//! anything it pins the zero-rate lane bitwise against the plain
//! `drain_window_sliced` path: a compiled-but-silent fault plan must
//! cost only the gate branches and change not a single byte of the
//! books.  Emits the standard bench JSON that `ci.sh` greps into
//! `BENCH_chaos.json`.

use std::hint::black_box;
use std::time::Duration;

use tiansuan::config::ChaosConfig;
use tiansuan::coordinator::downlink::{DownlinkItem, DownlinkQueue, DownlinkStats, ItemKind};
use tiansuan::link::{Link, LinkConfig, LinkStats, LossProfile};
use tiansuan::orbit::ContactWindow;
use tiansuan::sim::FaultPlan;
use tiansuan::util::bench;

const SATS: usize = 1000;
const ITEMS: usize = 8;
const WINDOWS: usize = 4;
const HORIZON_S: f64 = 6.0 * 3600.0;

/// One fault-intensity lane: `rate` is the total per-transfer
/// frame-fault probability; crash/dropout Poisson rates scale with it
/// so every class is live on the non-zero lanes.
fn lane_cfg(rate: f64) -> ChaosConfig {
    ChaosConfig {
        enabled: true,
        seed: 0xBE7C4,
        crash_rate_per_hour: rate * 25.0,
        frame_corrupt_rate: rate * 0.7,
        frame_truncate_rate: rate * 0.3,
        seu_rate: rate,
        dropout_rate_per_hour: rate * 20.0,
        ..ChaosConfig::default()
    }
}

/// Drain one satellite's backlog across its contact windows; `chaos:
/// None` is the plain pre-chaos drain, `Some` compiles the fault plan
/// and goes through the gated path (blackout check + ARQ injector).
fn run_backlog(chaos: Option<&ChaosConfig>, sat: usize) -> (LinkStats, DownlinkStats, usize) {
    let mut link = Link::new(LinkConfig::downlink(LossProfile::stable()), 7 + sat as u64);
    let mut queue = DownlinkQueue::new();
    for i in 0..ITEMS {
        queue.push(DownlinkItem {
            kind: if i % 2 == 0 { ItemKind::Results } else { ItemKind::Image },
            bytes: 20_000 + (i as u64 * 7919) % 50_000,
            ready_at: 0.0,
            tag: i as u64,
        });
    }
    let mut plan = chaos.map(|c| FaultPlan::compile(c, sat, HORIZON_S, 16));
    let mut delivered = 0usize;
    for k in 0..WINDOWS {
        let aos = k as f64 * 1800.0 + 300.0;
        let w = ContactWindow {
            aos,
            los: aos + 60.0,
            max_elevation_deg: 45.0,
            truncated: false,
            station_id: k % 2,
        };
        match plan.as_mut() {
            Some(p) => {
                if p.crashed_at(w.aos) {
                    continue; // blacked out: the pass never happens
                }
                let arq = p.arq;
                delivered += queue
                    .drain_window_sliced_chaos(&mut link, &w, true, None, &arq, &mut || {
                        p.next_frame_fault()
                    })
                    .len();
            }
            None => delivered += queue.drain_window_sliced(&mut link, &w, true).len(),
        }
    }
    (link.stats, queue.stats.clone(), delivered)
}

fn assert_link_bits(a: &LinkStats, b: &LinkStats, sat: usize) {
    assert_eq!(a.bytes_offered, b.bytes_offered, "sat {sat}: bytes_offered");
    assert_eq!(a.bytes_delivered, b.bytes_delivered, "sat {sat}: bytes_delivered");
    assert_eq!(a.packets_sent, b.packets_sent, "sat {sat}: packets_sent");
    assert_eq!(a.packets_lost, b.packets_lost, "sat {sat}: packets_lost");
    assert_eq!(a.retransmissions, b.retransmissions, "sat {sat}: retransmissions");
    assert_eq!(a.transfers_aborted, b.transfers_aborted, "sat {sat}: transfers_aborted");
    assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits(), "sat {sat}: busy_s");
    assert_eq!(b.frames_corrupted, 0, "sat {sat}: zero-rate lane corrupted a frame");
    assert_eq!(b.frames_truncated, 0, "sat {sat}: zero-rate lane truncated a frame");
    assert_eq!(b.retries, 0, "sat {sat}: zero-rate lane retried");
    assert_eq!(b.gave_up, 0, "sat {sat}: zero-rate lane gave up");
    assert_eq!(b.bytes_rejected, 0, "sat {sat}: zero-rate lane rejected bytes");
}

fn main() {
    // correctness pin before any timing: a zero-rate fault plan must be
    // bitwise inert against the plain drain, backlog for backlog
    let zero = lane_cfg(0.0);
    for sat in 0..32 {
        let (la, qa, da) = run_backlog(None, sat);
        let (lb, qb, db) = run_backlog(Some(&zero), sat);
        assert_eq!(da, db, "sat {sat}: delivered count drifted");
        assert_link_bits(&la, &lb, sat);
        assert_eq!(qa.items_delivered, qb.items_delivered, "sat {sat}: items_delivered");
        assert_eq!(qa.items_dropped, qb.items_dropped, "sat {sat}: items_dropped");
        assert_eq!(qa.bytes_dropped, qb.bytes_dropped, "sat {sat}: bytes_dropped");
        assert_eq!(qa.total_bytes(), qb.total_bytes(), "sat {sat}: total_bytes");
        assert_eq!(
            qa.latency_sum_s.to_bits(),
            qb.latency_sum_s.to_bits(),
            "sat {sat}: latency_sum_s"
        );
        assert_eq!(qa.station_bytes, qb.station_bytes, "sat {sat}: station attribution");
    }
    println!("zero-rate chaos lane bitwise identical to the plain drain over 32 backlogs");

    // plan compilation throughput at the heaviest lane
    let heavy = lane_cfg(0.10);
    let compile = bench::run(
        &format!("fault plan compile x{SATS}"),
        3,
        Duration::from_secs(1),
        || {
            for sat in 0..SATS {
                black_box(FaultPlan::compile(&heavy, sat, HORIZON_S, 16));
            }
        },
    );
    bench::json_line(
        "perf_chaos.plan_compile",
        &[
            ("plans", SATS as f64),
            ("median_ms", compile.median.as_secs_f64() * 1e3),
            ("plans_per_s", SATS as f64 / compile.median.as_secs_f64()),
        ],
    );

    // backlog drains at each fault intensity
    for (label, rate) in [("0pct", 0.0), ("1pct", 0.01), ("10pct", 0.10)] {
        let cfg = lane_cfg(rate);
        let mut totals = (0u64, 0u64, 0u64, 0usize); // retries, gave_up, rejected, delivered
        let stats = bench::run(
            &format!("chaos drain {label} x{SATS} sats"),
            3,
            Duration::from_secs(2),
            || {
                let mut t = (0u64, 0u64, 0u64, 0usize);
                for sat in 0..SATS {
                    let (l, _q, d) = run_backlog(Some(&cfg), sat);
                    t.0 += l.retries;
                    t.1 += l.gave_up;
                    t.2 += l.bytes_rejected;
                    t.3 += d;
                }
                totals = black_box(t);
            },
        );
        bench::json_line(
            "perf_chaos.drain",
            &[
                ("fault_rate_pct", rate * 100.0),
                ("sats", SATS as f64),
                ("items_per_sat", ITEMS as f64),
                ("median_ms", stats.median.as_secs_f64() * 1e3),
                ("sats_per_s", SATS as f64 / stats.median.as_secs_f64()),
                ("delivered", totals.3 as f64),
                ("retries", totals.0 as f64),
                ("gave_up", totals.1 as f64),
                ("bytes_rejected", totals.2 as f64),
            ],
        );
    }
}
