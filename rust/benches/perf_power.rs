//! Perf + scenario: battery-capacity sweep over an eclipse-heavy
//! mission, governed vs ungoverned.
//!
//! Artifact-free by design: it flies the governed power profile
//! ([`tiansuan::power::fly_mission`]) over a real orbital timeline
//! without touching the inference runtime, so CI can always record the
//! sweep (unlike `perf_engine`, which needs `artifacts/`).  Emits the
//! standard bench JSON (one object per line) that `ci.sh` greps into
//! `BENCH_power.json`.

use tiansuan::config::{EnergyConfig, PowerConfig, TimingConfig};
use tiansuan::orbit::{baoyun, beijing_station};
use tiansuan::power::{fly_mission, PowerState};
use tiansuan::sim::{DutyCycles, Timeline};
use tiansuan::util::bench;

fn main() {
    let sat = baoyun();
    let horizon = 6.0 * sat.period_s(); // six revolutions, ~38% eclipse each
    let period_s = 30.0;
    let timeline =
        Timeline::orbital(&TimingConfig::default(), &sat, &beijing_station(), horizon, 10.0);
    let active = DutyCycles { compute: 1.0, comm: 1.0, camera: 1.0 };
    let energy = EnergyConfig { pi_idle_floor: 0.0, comm_idle_floor: 0.0 };
    let periods = (horizon / period_s).ceil();

    println!(
        "=== perf_power: battery sweep over {:.1} h eclipse-heavy mission ({:.0}% sunlit) ===",
        horizon / 3600.0,
        100.0 * timeline.sunlit_fraction(0.0, horizon)
    );
    for battery_wh in [20.0, 40.0, 60.0, 80.0, 120.0, 240.0] {
        for governed in [true, false] {
            let power = PowerConfig {
                enabled: true,
                battery_wh,
                panel_w: 95.0,
                cosine_derate: 0.8,
                initial_soc: 0.4,
                soc_defer: if governed { 0.6 } else { 0.0 },
                soc_critical: if governed { 0.3 } else { 0.0 },
                ..PowerConfig::default()
            };
            let mut state = PowerState::new(&power, &energy);
            let t0 = std::time::Instant::now();
            fly_mission(&mut state, &timeline, active, period_s);
            let wall = t0.elapsed().as_secs_f64();
            let s = state.stats;
            println!(
                "battery {battery_wh:>5.0} Wh {}: SoC min {:>4.1}% mean {:>4.1}%, \
                 {:.0}/{:.0} Wh gen/load, {:>4} deferred {:>4} shed, {:.2} Wh unmet",
                if governed { "governed  " } else { "ungoverned" },
                100.0 * s.min_soc_frac,
                100.0 * s.mean_soc_frac(),
                s.generated_wh,
                s.consumed_wh,
                s.scenes_deferred,
                s.scenes_shed,
                s.shortfall_wh,
            );
            bench::json_line(
                "perf_power.battery_sweep",
                &[
                    ("battery_wh", battery_wh),
                    ("governed", if governed { 1.0 } else { 0.0 }),
                    ("min_soc", s.min_soc_frac),
                    ("mean_soc", s.mean_soc_frac()),
                    ("generated_wh", s.generated_wh),
                    ("consumed_wh", s.consumed_wh),
                    ("shortfall_wh", s.shortfall_wh),
                    ("deferred", s.scenes_deferred as f64),
                    ("shed", s.scenes_shed as f64),
                    ("wall_s", wall),
                    ("periods_per_s", periods / wall.max(1e-12)),
                ],
            );
        }
    }

    // pure integration hot-loop throughput (the per-period cost the
    // constellation driver pays when power is enabled)
    let power = PowerConfig { enabled: true, ..PowerConfig::default() };
    let stats = bench::run(
        "power/fly_mission/6rev",
        10,
        std::time::Duration::from_millis(500),
        || {
            let mut state = PowerState::new(&power, &energy);
            fly_mission(&mut state, &timeline, active, period_s);
            std::hint::black_box(state.stats.min_soc_frac);
        },
    );
    bench::json_line(
        "perf_power.integrate",
        &[
            ("periods", periods),
            ("median_s", stats.median.as_secs_f64()),
            ("periods_per_s", periods / stats.median.as_secs_f64().max(1e-12)),
        ],
    );
}
