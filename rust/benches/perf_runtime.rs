//! Perf: PJRT execution layer — per-call latency and batch-sweep
//! throughput for every artifact.  This is the L3-side measurement of the
//! L1/L2 stack (EXPERIMENTS.md §Perf).

use std::time::Duration;

use tiansuan::runtime::{Model, Runtime};
use tiansuan::util::bench;
use tiansuan::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.warmup()?;
    rt.calibrate()?; // cost-based batch planning (EXPERIMENTS.md §Perf)
    let t = rt.manifest.tile;
    let mut rng = Rng::new(7);

    println!("=== perf: PJRT runtime ({} / batches {:?}) ===", rt.platform(), rt.manifest.batch_sizes);
    for model in [Model::CloudScore, Model::Tiny, Model::TinyV2, Model::Heavy] {
        for &b in &rt.manifest.batch_sizes {
            let input: Vec<f32> = (0..b * t * t * 3).map(|_| rng.f32()).collect();
            let stats = bench::run(
                &format!("{}/b{}", model.stem(), b),
                10,
                Duration::from_millis(800),
                || {
                    rt.execute_exact(model, b, &input).unwrap();
                },
            );
            println!(
                "  -> {:>8.1} tiles/s at batch {b}",
                b as f64 / stats.median.as_secs_f64()
            );
        }
    }
    Ok(())
}
