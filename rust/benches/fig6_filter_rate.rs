//! Fig 6 — "The filter rate of redundant data in orbit on DOTA."
//!
//! Regenerates the figure's series: filter rate for the two dataset
//! versions across fragment sizes {32, 64, 128}, plus wallclock for the
//! split+filter stage (the onboard preprocessing budget).

use tiansuan::config::Config;
use tiansuan::coordinator::Pipeline;
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;
use tiansuan::util::bench;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    println!("=== Fig 6: filter rate of redundant data in orbit ===");
    println!("{:<8} {:>8} {:>8} {:>12}  (paper: v1 ≈90%, v2 ≈40%, flat in frag)",
             "version", "frag", "tiles", "filter rate");
    for version in [Version::V1, Version::V2] {
        for frag in [32usize, 64, 128] {
            let mut cfg = Config::default();
            cfg.fragment_px = frag;
            let pipeline = Pipeline::new(&rt, cfg);
            let (r, dt) = bench::once(
                &format!("fig6/{}/frag{}", version.name(), frag),
                || pipeline.run_scenario(version, 6).unwrap(),
            );
            println!("{:<8} {:>8} {:>8} {:>11.1}%   ({:.2}s)",
                     r.version, frag, r.tiles_total, 100.0 * r.filter_rate(), dt.as_secs_f64());
        }
    }
    Ok(())
}
