//! Fig 7 — "Accuracy (mAP in object detection task) of in-orbit vs.
//! collaborative inference."
//!
//! Regenerates the figure's two scenario groups plus the two headline
//! numbers the paper derives from it: ≈50% average accuracy improvement
//! and 90% reduction in returned data.

use tiansuan::config::Config;
use tiansuan::coordinator::Pipeline;
use tiansuan::data::Version;
use tiansuan::runtime::Runtime;
use tiansuan::util::bench;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    rt.warmup()?;
    rt.calibrate()?; // cost-based batch planning (EXPERIMENTS.md §Perf)
    println!("=== Fig 7: accuracy of in-orbit vs collaborative inference ===");
    println!("{:<10} {:>10} {:>10} {:>12} {:>14}", "scenario", "in-orbit", "collab",
             "improvement", "data reduction");
    let mut impr = Vec::new();
    for version in [Version::V1, Version::V2] {
        let pipeline = Pipeline::new(&rt, Config::default());
        let (r, _) = bench::once(&format!("fig7/{}", version.name()), || {
            pipeline.run_scenario(version, 10).unwrap()
        });
        impr.push(r.accuracy_improvement());
        println!("{:<10} {:>10.3} {:>10.3} {:>11.0}% {:>13.1}%",
                 r.version, r.map_inorbit, r.map_collab,
                 100.0 * r.accuracy_improvement(), 100.0 * r.data_reduction());
    }
    println!("average improvement {:.0}%  (paper: +44% and +52%, ≈50% average; reduction 90%)",
             100.0 * impr.iter().sum::<f64>() / impr.len() as f64);
    Ok(())
}
