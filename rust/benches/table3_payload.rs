//! Table 3 — "The power of payloads subsystem of Baoyun satellite," plus
//! the two derived headlines: computing ≈33% of payload energy and ≈17%
//! of total onboard energy (H2).

use tiansuan::config::Config;
use tiansuan::coordinator::Pipeline;
use tiansuan::data::Version;
use tiansuan::energy::{EnergyMeter, Payload};
use tiansuan::orbit::{baoyun, beijing_station, contact_windows};
use tiansuan::runtime::Runtime;
use tiansuan::util::bench;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    let pipeline = Pipeline::new(&rt, Config::default());
    let (r, _) = bench::once("table3/measure_duty", || {
        pipeline.run_scenario(Version::V2, 6).unwrap()
    });

    let windows = contact_windows(&baoyun(), &beijing_station(), 0.0, 86_400.0, 10.0);
    let comm_duty = windows.iter().map(|w| w.duration_s()).sum::<f64>() / 86_400.0;
    let mut m = EnergyMeter::new();
    m.advance(2.0 * baoyun().period_s(), r.compute_duty, comm_duty, 0.1);

    println!("=== Table 3: payload power (W), simulated vs paper ===");
    let paper = [0.09, 6.26, 5.68, 0.95, 6.12, 8.78];
    for (p, want) in Payload::all().iter().zip(paper) {
        let got = m.payload_j(*p) / m.elapsed_s;
        println!("{:<14} {:>8.2}   paper {:>6.2}", p.name(), got, want);
    }
    println!(
        "computing share: {:.1}% of payloads (paper ≈33%), {:.1}% of total (paper ≈17%)",
        100.0 * m.compute_share_of_payloads(),
        100.0 * m.compute_share()
    );
    assert!((0.10..0.25).contains(&m.compute_share()), "17%-band violated: {}", m.compute_share());
    Ok(())
}
