//! Table 1 — satellite platform specifications, validated against the
//! link + orbit models: the configured downlink/uplink rates must be the
//! rates the simulated links actually achieve, and the 500 km orbit must
//! produce the pass structure the paper's handover model assumes.

use tiansuan::config::{baoyun_platform, chuangxingleishen_platform};
use tiansuan::link::{Link, LinkConfig, LossProfile};
use tiansuan::orbit::{baoyun, beijing_station, contact_windows};
use tiansuan::util::bench;

fn main() {
    println!("=== Table 1: platform specifications (validated) ===");
    for p in [baoyun_platform(), chuangxingleishen_platform()] {
        println!("{:<20} alt {}±50 km  mass {} kg  load {} U  size {} U  {}",
                 p.name, p.orbital_altitude_km, p.mass_kg, p.load_size_u, p.size_u,
                 p.operating_system);
    }

    // downlink rate envelope: lossless 40 Mbps link must move 5 MB in ~1 s
    let stats = bench::run("table1/downlink_5MB", 5, std::time::Duration::from_millis(200), || {
        let mut link = Link::new(
            LinkConfig { rate_bps: 40e6, mtu: 1400, loss: LossProfile::stable(), max_tries: 8 },
            1,
        );
        let t = link.transmit(5_000_000, 10.0);
        assert!(t.completed);
        assert!((0.9..1.3).contains(&t.elapsed_s), "5 MB at 40 Mbps took {}s", t.elapsed_s);
    });
    let _ = stats;

    // orbit: 500 km period + daily pass structure over Beijing
    let sat = baoyun();
    println!("orbital period {:.1} s ({:.1} min)", sat.period_s(), sat.period_s() / 60.0);
    let (windows, _) = bench::once("table1/contact_windows_24h", || {
        contact_windows(&sat, &beijing_station(), 0.0, 86_400.0, 10.0)
    });
    let total: f64 = windows.iter().map(|w| w.duration_s()).sum();
    println!("{} passes/day over Beijing, {:.0} s total contact — the scarcity that motivates onboard filtering",
             windows.len(), total);
    assert!(!windows.is_empty());
}
