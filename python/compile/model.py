"""L2 JAX models: TinyDet (onboard) and HeavyDet (ground).

The paper deploys YOLOv3-tiny on the satellite and YOLOv3 on the ground
(§IV).  We reproduce the *architectural relationship* — a lightweight
low-precision detector vs a large high-precision one — with single-scale
YOLO-style grid detectors sized for CPU-interpret Pallas:

    TinyDet : 3 stride-2 3x3 convs  (12, 24, 48 ch)  + 1x1 head
    HeavyDet: 6 3x3 convs, alternating stride 2/1 (24..96 ch) + 1x1 head

Every conv is im2col + the L1 Pallas ``fused_matmul`` kernel (bias +
LeakyReLU fused); the head is decoded by the L1 ``decode_head`` kernel.
``impl="ref"`` swaps in the pure-jnp oracles — identical math — which is
what the build-time training loop differentiates through.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import decode as kdecode
from .kernels import matmul as kmatmul
from .kernels import ref as kref

TILE = 64
CLASSES = 8
GRID = 8
STRIDE = float(TILE // GRID)  # 8 px per cell
ANCHOR = (16.0, 16.0)
HEAD_D = 5 + CLASSES  # [tx, ty, tw, th, obj, cls0..cls7]

# (cin, cout, stride) per 3x3 conv layer.
TINY_ARCH = [(3, 12, 2), (12, 24, 2), (24, 48, 2)]
HEAVY_ARCH = [(3, 24, 2), (24, 24, 1), (24, 48, 2), (48, 48, 1), (48, 96, 2), (96, 96, 1)]
ARCHS = {"tiny": TINY_ARCH, "heavy": HEAVY_ARCH}


def init_params(key: jax.Array, arch_name: str):
    """He-normal init. Conv weights are stored pre-flattened as (9*cin, cout)
    in (dy, dx, cin) patch order — exactly the im2col layout — plus the
    (feat, HEAD_D) 1x1 head."""
    arch = ARCHS[arch_name]
    params = []
    for cin, cout, _stride in arch:
        key, k1 = jax.random.split(key)
        fan_in = 9 * cin
        w = jax.random.normal(k1, (fan_in, cout), jnp.float32) * jnp.sqrt(2.0 / fan_in)
        params.append((w, jnp.zeros((cout,), jnp.float32)))
    feat = arch[-1][1]
    key, k1 = jax.random.split(key)
    wh = jax.random.normal(k1, (feat, HEAD_D), jnp.float32) * jnp.sqrt(1.0 / feat)
    # Bias objectness negative so early training isn't drowned in false
    # positives (standard focal/YOLO init trick).
    bh = jnp.zeros((HEAD_D,), jnp.float32).at[4].set(-3.0)
    params.append((wh, bh))
    return params


def im2col(x: jax.Array, stride: int):
    """(B, H, W, C) -> ((B*Ho*Wo, 9C), (B, Ho, Wo)) for a SAME-padded 3x3.

    Patch features are ordered (dy, dx, cin) to match ``init_params``.
    """
    b, h, w, c = x.shape
    ho, wo = -(-h // stride), -(-w // stride)
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(
                xp[:, dy : dy + (ho - 1) * stride + 1 : stride,
                   dx : dx + (wo - 1) * stride + 1 : stride, :]
            )
    patches = jnp.concatenate(cols, axis=-1)  # (B, Ho, Wo, 9C)
    return patches.reshape(b * ho * wo, 9 * c), (b, ho, wo)


def _mm(impl: str, interpret: bool):
    if impl == "pallas":
        def mm(x, w, b, activation="leaky_relu"):
            return kmatmul.fused_matmul(x, w, b, activation=activation, interpret=interpret)
        return mm
    if impl == "ref":
        return kref.ref_fused_matmul
    raise ValueError(f"unknown impl {impl!r}")


def forward_raw(params, x: jax.Array, arch_name: str, *, impl: str = "ref",
                interpret: bool = True) -> jax.Array:
    """Backbone + head, NO decode: (B, T, T, 3) -> raw (B*G*G, HEAD_D) rows.

    This is what the training loss consumes (targets live in t-space).
    """
    arch = ARCHS[arch_name]
    mm = _mm(impl, interpret)
    for (w, b), (_cin, cout, stride) in zip(params[:-1], arch):
        cols, (bb, ho, wo) = im2col(x, stride)
        y = mm(cols, w, b)
        x = y.reshape(bb, ho, wo, cout)
    bsz, g, g2, feat = x.shape
    assert g == GRID and g2 == GRID, f"head grid {g}x{g2} != {GRID}"
    wh, bh = params[-1]
    return mm(x.reshape(bsz * g * g, feat), wh, bh, activation="none")


def forward(params, x: jax.Array, arch_name: str, *, impl: str = "ref",
            interpret: bool = True) -> jax.Array:
    """Full inference: (B, T, T, 3) -> decoded (B, G*G, HEAD_D).

    Row layout: [cx, cy, w, h, obj, p_cls0..p_cls7] in tile pixel coords.
    """
    bsz = x.shape[0]
    t = forward_raw(params, x, arch_name, impl=impl, interpret=interpret)
    offsets = jnp.tile(kdecode.make_offsets(GRID), (bsz, 1))
    if impl == "pallas":
        d = kdecode.decode_head(
            t, offsets, stride=STRIDE, anchor_w=ANCHOR[0], anchor_h=ANCHOR[1],
            interpret=interpret,
        )
    else:
        d = kref.ref_decode_head(
            t, offsets, stride=STRIDE, anchor_w=ANCHOR[0], anchor_h=ANCHOR[1]
        )
    return d.reshape(bsz, GRID * GRID, HEAD_D)


def param_count(params) -> int:
    return sum(int(w.size + b.size) for w, b in params)
