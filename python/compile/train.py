"""Build-time training of TinyDet / HeavyDet on SynthDOTA.

Runs ONCE inside ``make artifacts`` (never on the request path).  The loss
is a single-anchor YOLO objective:

    L = w_obj * BCE(obj) + w_noobj * BCE(noobj)
      + w_coord * [ MSE(sigmoid(txy), frac_offset) + MSE(twh, log(wh/anchor)) ]
      + w_cls * BCE(class one-hot)                       (object cells only)

Training differentiates through the pure-jnp ``impl="ref"`` forward — the
oracle math is bit-compatible with the Pallas kernels (pytest enforces
allclose), so the trained weights transfer exactly to the Pallas inference
graph that aot.py exports.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as sdata
from . import model as smodel

W_OBJ = 1.0
W_NOOBJ = 0.35
W_COORD = 5.0
W_CLS = 1.0


def build_targets(all_boxes, grid: int = smodel.GRID, stride: float = smodel.STRIDE):
    """List-of-box-lists -> (B, G*G, HEAD_D) target tensor + obj mask.

    Target layout matches the raw head: [tx*, ty*, tw*, th*, obj, onehot...]
    where tx*,ty* are fractional cell offsets (compared to sigmoid(t)) and
    tw*,th* are log(wh / anchor) (compared to raw t).
    """
    b = len(all_boxes)
    tgt = np.zeros((b, grid * grid, smodel.HEAD_D), np.float32)
    for i, boxes in enumerate(all_boxes):
        for cx, cy, w, h, cls in boxes:
            gx = min(int(cx / stride), grid - 1)
            gy = min(int(cy / stride), grid - 1)
            cell = gy * grid + gx
            tgt[i, cell, 0] = cx / stride - gx
            tgt[i, cell, 1] = cy / stride - gy
            tgt[i, cell, 2] = np.log(max(w, 2.0) / smodel.ANCHOR[0])
            tgt[i, cell, 3] = np.log(max(h, 2.0) / smodel.ANCHOR[1])
            tgt[i, cell, 4] = 1.0
            tgt[i, cell, 5:] = 0.0
            tgt[i, cell, 5 + cls] = 1.0
    return jnp.asarray(tgt)


def _bce(logits, labels):
    # Numerically-stable sigmoid BCE.
    return jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))


def yolo_loss(params, x, tgt, arch_name: str):
    bsz = x.shape[0]
    t = smodel.forward_raw(params, x, arch_name, impl="ref")
    t = t.reshape(bsz, smodel.GRID * smodel.GRID, smodel.HEAD_D)
    obj = tgt[..., 4]
    noobj = 1.0 - obj

    obj_bce = _bce(t[..., 4], obj)
    l_obj = W_OBJ * jnp.sum(obj_bce * obj) / (jnp.sum(obj) + 1.0)
    l_noobj = W_NOOBJ * jnp.sum(obj_bce * noobj) / (jnp.sum(noobj) + 1.0)

    xy = jax.nn.sigmoid(t[..., 0:2])
    l_xy = jnp.sum(obj[..., None] * (xy - tgt[..., 0:2]) ** 2)
    l_wh = jnp.sum(obj[..., None] * (t[..., 2:4] - tgt[..., 2:4]) ** 2)
    l_coord = W_COORD * (l_xy + l_wh) / (jnp.sum(obj) + 1.0)

    cls_bce = _bce(t[..., 5:], tgt[..., 5:])
    l_cls = W_CLS * jnp.sum(obj[..., None] * cls_bce) / (jnp.sum(obj) + 1.0)
    return l_obj + l_noobj + l_coord + l_cls


def adam_init(params):
    zeros = lambda p: [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in p]
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    new_p, new_m, new_v = [], [], []
    for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(
        params, grads, state["m"], state["v"]
    ):
        out_wb, out_m, out_v = [], [], []
        for p, g, m, v in ((w, gw, mw, vw), (b, gb, mb, vb)):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** t.astype(jnp.float32))
            vhat = v / (1 - b2 ** t.astype(jnp.float32))
            out_wb.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            out_m.append(m)
            out_v.append(v)
        new_p.append(tuple(out_wb))
        new_m.append(tuple(out_m))
        new_v.append(tuple(out_v))
    return new_p, {"m": new_m, "v": new_v, "t": t}


def train(
    arch_name: str,
    steps: int,
    *,
    seed: int = 7,
    batch: int = 32,
    lr: float = 1.5e-3,
    log_every: int = 50,
    log=print,
):
    """Train one detector; returns (params, final_loss_estimate, history)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = smodel.init_params(key, arch_name)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, tgt):
        loss, grads = jax.value_and_grad(yolo_loss)(params, x, tgt, arch_name)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    history = []
    t0 = time.time()
    ema = None
    for i in range(steps):
        imgs, boxes = sdata.gen_training_batch(rng, batch)
        tgt = build_targets(boxes)
        params, opt, loss = step(params, opt, jnp.asarray(imgs), tgt)
        loss = float(loss)
        ema = loss if ema is None else 0.95 * ema + 0.05 * loss
        if i % log_every == 0 or i == steps - 1:
            history.append((i, ema))
            log(f"[train {arch_name}] step {i:4d}/{steps} loss={loss:.4f} ema={ema:.4f} "
                f"({time.time()-t0:.0f}s)")
    return params, ema, history
