"""AOT compile path: train on SynthDOTA, lower Pallas-kernel inference
graphs, and emit HLO **text** artifacts for the rust runtime.

HLO text — NOT ``lowered.compile()`` or serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py).

Emitted into ``artifacts/``:

    tinydet_b{B}.hlo.txt      onboard detector  (B, 64, 64, 3) -> (B, 64, 13)
    tinydet_v2_b{B}.hlo.txt   incrementally-retrained onboard detector
    heavydet_b{B}.hlo.txt     ground detector   (same interface)
    cloudscore_b{B}.hlo.txt   redundancy filter (B, 64, 64, 3) -> (B, 3)
    weights_{model}.npz       raw trained weights (federated / incremental)
    manifest.json             shapes, constants, dataset spec, training log

Trained weights are baked into the HLO as constants, so the rust side
feeds only image batches.  Python runs ONCE; never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as sdata
from . import model as smodel
from . import train as strain
from .kernels import cloudscore as kcloud

BATCH_SIZES = (1, 8)

# Default training budgets.  tiny gets deliberately fewer steps than heavy:
# the paper's onboard model is "lightweight, low-precision" — the accuracy
# gap (Fig 7) is the phenomenon under study.  tiny_v2 is the same arch
# trained longer: the IncrementalLearning artifact that the Sedna layer
# hot-swaps onto the satellite (paper §3.4).
# Calibrated so the onboard model is usable-but-clearly-weaker (paper:
# YOLOv3-tiny vs YOLOv3 ⇒ collaborative inference improves mAP ≈50%).
STEPS = {"tiny": 1000, "tiny_v2": 1800, "heavy": 900}
FAST_STEPS = {"tiny": 12, "tiny_v2": 20, "heavy": 16}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: baked weights must survive the text round-trip
    # (default printing elides big literals as "{...}", which the rust-side
    # HLO text parser cannot reconstruct).
    return comp.as_hlo_text(print_large_constants=True)


def export_detector(params, arch_name: str, batch: int) -> str:
    """Lower the Pallas-kernel inference graph with baked weights."""
    const_params = [(jnp.asarray(w), jnp.asarray(b)) for w, b in params]

    def infer(x):
        return (smodel.forward(const_params, x, arch_name, impl="pallas",
                               interpret=True),)

    spec = jax.ShapeDtypeStruct((batch, smodel.TILE, smodel.TILE, 3), jnp.float32)
    return to_hlo_text(jax.jit(infer).lower(spec))


def export_cloudscore(batch: int) -> str:
    def score(x):
        return (kcloud.cloud_score(x, interpret=True),)

    spec = jax.ShapeDtypeStruct((batch, smodel.TILE, smodel.TILE, 3), jnp.float32)
    return to_hlo_text(jax.jit(score).lower(spec))


def save_weights(path: pathlib.Path, params) -> str:
    arrs = {}
    for i, (w, b) in enumerate(params):
        arrs[f"w{i}"] = np.asarray(w)
        arrs[f"b{i}"] = np.asarray(b)
    np.savez(path, **arrs)
    h = hashlib.sha256(path.read_bytes()).hexdigest()[:16]
    return h


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny training budgets (CI / pytest smoke)")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    steps = FAST_STEPS if args.fast else STEPS

    manifest = {
        "tile": smodel.TILE,
        "grid": smodel.GRID,
        "stride": smodel.STRIDE,
        "anchor": list(smodel.ANCHOR),
        "classes": smodel.CLASSES,
        "class_names": sdata.CLASS_NAMES,
        "head_d": smodel.HEAD_D,
        "batch_sizes": list(BATCH_SIZES),
        "white_thresh": kcloud.WHITE_THRESH,
        "redundant_white_frac": sdata.REDUNDANT_WHITE_FRAC,
        "dataset_versions": sdata.VERSIONS,
        "fast": args.fast,
        "models": {},
    }

    # --- train ---------------------------------------------------------
    trained = {}
    for name, arch in (("tiny", "tiny"), ("tiny_v2", "tiny"), ("heavy", "heavy")):
        # tiny_v2 continues from a different seed stream but is the same
        # arch trained ~3x longer (the incremental-learning update).
        params, final_loss, history = strain.train(
            arch, steps[name], seed=args.seed + (1 if name == "tiny_v2" else 0)
        )
        trained[name] = (params, arch)
        whash = save_weights(out / f"weights_{name}.npz", params)
        manifest["models"][name] = {
            "arch": arch,
            "steps": steps[name],
            "final_loss_ema": final_loss,
            "param_count": smodel.param_count(params),
            "weights_sha256_16": whash,
            "loss_history": history,
            "files": {},
        }

    # --- lower + emit ----------------------------------------------------
    file_map = {"tiny": "tinydet", "tiny_v2": "tinydet_v2", "heavy": "heavydet"}
    for name, (params, arch) in trained.items():
        for b in BATCH_SIZES:
            text = export_detector(params, arch, b)
            fname = f"{file_map[name]}_b{b}.hlo.txt"
            (out / fname).write_text(text)
            manifest["models"][name]["files"][str(b)] = fname
            print(f"wrote {fname} ({len(text)} chars)")

    manifest["cloudscore_files"] = {}
    for b in BATCH_SIZES:
        text = export_cloudscore(b)
        fname = f"cloudscore_b{b}.hlo.txt"
        (out / fname).write_text(text)
        manifest["cloudscore_files"][str(b)] = fname
        print(f"wrote {fname} ({len(text)} chars)")

    # --- numeric parity fixtures for the rust integration tests ---------
    # A deterministic input batch + the python-side decoded outputs, dumped
    # as raw little-endian f32.  rust/tests/runtime_parity.rs re-runs the
    # HLO artifacts on PJRT and asserts allclose against these.
    rng = np.random.default_rng(2024)
    fix = rng.uniform(0, 1, size=(1, smodel.TILE, smodel.TILE, 3)).astype(np.float32)
    (out / "fixture_input_b1.bin").write_bytes(fix.tobytes())
    for name, (params, arch) in trained.items():
        got = np.asarray(
            smodel.forward([(jnp.asarray(w), jnp.asarray(b)) for w, b in params],
                           jnp.asarray(fix), arch, impl="pallas")
        ).astype(np.float32)
        (out / f"fixture_{file_map[name]}_b1_out.bin").write_bytes(got.tobytes())
    cs = np.asarray(kcloud.cloud_score(jnp.asarray(fix))).astype(np.float32)
    (out / "fixture_cloudscore_b1_out.bin").write_bytes(cs.tobytes())

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote manifest.json; models: "
          f"{ {k: v['param_count'] for k, v in manifest['models'].items()} }")


if __name__ == "__main__":
    main()
