"""L1 perf-pass analysis: BlockSpec sweep for the fused matmul kernel.

interpret=True wallclock is CPU-numpy time, NOT a TPU proxy — so the L1
optimization target is structural: per-step VMEM residency must fit the
16 MiB budget and MXU lane utilization should be maximal for the
detectors' actual conv shapes (DESIGN.md §Hardware-Adaptation).

    cd python && python -m compile.perf_sweep

Prints, per conv layer of both detectors and per candidate block_m, the
VMEM footprint and MXU utilization estimate, and the chosen block.  The
result (block_m=128 for every layer) is recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from . import model as smodel
from .kernels import matmul as km

VMEM_BUDGET = 16 * 1024 * 1024
CANDIDATES = (32, 64, 128, 256, 512)


def layer_shapes(arch_name: str, batch: int):
    """Yield (label, M, K, N) for each im2col matmul in the forward pass."""
    h = smodel.TILE
    for i, (cin, cout, stride) in enumerate(smodel.ARCHS[arch_name]):
        ho = -(-h // stride)
        yield f"{arch_name}/conv{i}", batch * ho * ho, 9 * cin, cout
        h = ho
    feat = smodel.ARCHS[arch_name][-1][1]
    yield f"{arch_name}/head", batch * smodel.GRID * smodel.GRID, feat, smodel.HEAD_D


def main() -> None:
    print(f"{'layer':<14} {'M':>6} {'K':>5} {'N':>4} | " +
          " | ".join(f"bm={c:<4}" for c in CANDIDATES) + " | chosen")
    for arch in ("tiny", "heavy"):
        for label, m, k, n in layer_shapes(arch, batch=8):
            cells = []
            best, best_score = None, -1.0
            for bm in CANDIDATES:
                vmem = km.vmem_footprint(bm, k, n)
                util = km.mxu_utilization_estimate(m, k, n, bm)
                fits = vmem <= VMEM_BUDGET
                # prefer max utilization among fitting blocks; break ties
                # toward larger blocks (fewer grid steps = less loop
                # overhead in the lowered while-loop)
                score = util + (bm / 1e6) if fits else -1.0
                if score > best_score:
                    best, best_score = bm, score
                cells.append(f"{util:4.2f}{'*' if not fits else ' '}")
            print(f"{label:<14} {m:>6} {k:>5} {n:>4} | " +
                  " | ".join(f"{c:<7}" for c in cells) + f" | {best}")
    print("(* = exceeds 16 MiB VMEM budget; util = MXU lane utilization estimate)")
    print(f"default block_m = {km.DEFAULT_BLOCK_M}")


if __name__ == "__main__":
    main()
