"""L1 Pallas kernel: per-tile cloud-cover statistics.

The onboard redundancy filter (paper §II: 80-90% of raw data over SW China
is invalid due to cloud cover; Fig 6) scores each tile before any detector
runs.  One grid step reduces one (T, T, 3) tile to three scalars:

    lum        mean luminance (r+g+b)/3
    var        luminance variance
    white_frac fraction of pixels whose min-channel exceeds WHITE_THRESH
               (clouds are bright AND desaturated — high min-channel)

The rust coordinator thresholds ``white_frac`` to drop redundant tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WHITE_THRESH = 0.72
N_STATS = 3


def _cloudscore_kernel(x_ref, o_ref):
    x = x_ref[...]  # (1, T, T, 3)
    lum = jnp.mean(x, axis=-1)  # (1, T, T)
    mean_lum = jnp.mean(lum)
    var_lum = jnp.mean((lum - mean_lum) ** 2)
    white = jnp.mean((jnp.min(x, axis=-1) > WHITE_THRESH).astype(jnp.float32))
    o_ref[...] = jnp.stack([mean_lum, var_lum, white]).reshape(1, N_STATS)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cloud_score(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """(B, T, T, 3) f32 in [0,1] -> (B, 3) [mean_lum, var_lum, white_frac]."""
    b, t, t2, c = x.shape
    assert t == t2 and c == 3, f"expected (B,T,T,3), got {x.shape}"
    return pl.pallas_call(
        _cloudscore_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, t, t, 3), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, N_STATS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, N_STATS), jnp.float32),
        interpret=interpret,
    )(x)
