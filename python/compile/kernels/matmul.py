"""L1 Pallas kernel: tiled matmul with fused bias + LeakyReLU epilogue.

This is the hot-spot of both detectors: every conv layer is lowered to
im2col + this kernel (M = B*H'*W' activation rows, K = kh*kw*Cin patch
width, N = Cout).  BlockSpec tiles M into MXU-height panels while keeping
the K-panel and the full weight matrix resident — on a TPU this maps the
(M_blk x K) x (K x N) product onto the 128x128 systolic array; here it runs
under ``interpret=True`` because the CPU PJRT plugin cannot execute Mosaic
custom-calls (see DESIGN.md §Hardware-Adaptation).

The epilogue (bias add + LeakyReLU) is fused so activations never
round-trip to HBM between the matmul and the nonlinearity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped row-panel height.  K and N stay whole per block: for
# our detector shapes (K <= 864, N <= 96) one weight panel fits comfortably
# in a VMEM-scale budget (see vmem_footprint()).
DEFAULT_BLOCK_M = 128
LEAKY_SLOPE = 0.1


def _fused_matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One grid step: (block_m, K) @ (K, N) + b, then optional LeakyReLU."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if activation == "leaky_relu":
        acc = jnp.where(acc >= 0.0, acc, LEAKY_SLOPE * acc)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "block_m", "interpret"))
def fused_matmul(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "leaky_relu",
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = True,
) -> jax.Array:
    """Compute ``act(x @ w + b)`` with a Pallas row-tiled kernel.

    x: (M, K) f32; w: (K, N) f32; b: (N,) f32 -> (M, N) f32.
    M is padded up to a multiple of ``block_m``; the pad rows are sliced off
    before returning, so callers see exact shapes.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm = min(block_m, max(8, m))
    m_pad = (-m) % bm
    if m_pad:
        x = jnp.pad(x, ((0, m_pad), (0, 0)))
    grid = ((m + m_pad) // bm,)

    out = pl.pallas_call(
        functools.partial(_fused_matmul_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m + m_pad, n), jnp.float32),
        interpret=interpret,
    )(x, w, b.reshape(1, n))
    return out[:m] if m_pad else out


def vmem_footprint(block_m: int, k: int, n: int, bytes_per_el: int = 4) -> int:
    """Bytes resident per grid step: x panel + weight panel + bias + out tile.

    Used by the perf pass (EXPERIMENTS.md §Perf) to check the BlockSpec fits
    a 16 MiB TPU VMEM budget — interpret-mode wallclock is NOT a TPU proxy,
    so we optimise structure via this estimate instead.
    """
    return bytes_per_el * (block_m * k + k * n + n + block_m * n)


def mxu_utilization_estimate(m: int, k: int, n: int, block_m: int = DEFAULT_BLOCK_M) -> float:
    """Fraction of MXU lanes doing useful work for this problem shape.

    The 128x128 MXU multiplies 128-row by 128-col panels; ragged edges in
    M (pad rows) and small K/N waste lanes.  This is the structural
    efficiency metric we optimise block shapes against.
    """
    m_eff = m / (((m + block_m - 1) // block_m) * block_m)
    k_eff = min(k, 128) / 128 if k < 128 else 1.0
    n_eff = min(n, 128) / 128 if n < 128 else 1.0
    return m_eff * k_eff * n_eff
