"""L1 Pallas kernel: YOLO-style detection-head decode.

Transforms raw head activations t[..., 5+C] into image-space boxes +
calibrated scores, fused in one elementwise pass:

    cx, cy = (sigmoid(t[:, 0:2]) + cell_offset) * stride
    w,  h  = exp(clip(t[:, 2:4])) * anchor
    obj    = sigmoid(t[:, 4])
    cls    = sigmoid(t[:, 5:])

Rows are the flattened (B, G, G) cells; ``offsets`` carries the (gx, gy)
cell coordinates so the kernel itself is position-independent and tiles
cleanly over rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 64
WH_CLIP = 6.0  # exp clamp: keeps decoded boxes finite for wild logits


def _decode_kernel(t_ref, off_ref, o_ref, *, stride: float, anchor_w: float, anchor_h: float):
    t = t_ref[...]
    off = off_ref[...]
    xy = (jax.nn.sigmoid(t[:, 0:2]) + off) * stride
    wh_log = jnp.clip(t[:, 2:4], -WH_CLIP, WH_CLIP)
    # anchor_w/h are python-float compile-time constants (a captured jnp
    # array would trip pallas's no-captured-consts rule).
    w = jnp.exp(wh_log[:, 0:1]) * anchor_w
    h = jnp.exp(wh_log[:, 1:2]) * anchor_h
    rest = jax.nn.sigmoid(t[:, 4:])
    o_ref[...] = jnp.concatenate([xy, w, h, rest], axis=-1)


@functools.partial(
    jax.jit, static_argnames=("stride", "anchor_w", "anchor_h", "block_r", "interpret")
)
def decode_head(
    t: jax.Array,
    offsets: jax.Array,
    *,
    stride: float,
    anchor_w: float,
    anchor_h: float,
    block_r: int = DEFAULT_BLOCK_R,
    interpret: bool = True,
) -> jax.Array:
    """Decode (R, 5+C) raw head rows with (R, 2) cell offsets -> (R, 5+C)."""
    r, d = t.shape
    assert offsets.shape == (r, 2), f"offsets shape {offsets.shape} != ({r}, 2)"
    br = min(block_r, max(8, r))
    r_pad = (-r) % br
    if r_pad:
        t = jnp.pad(t, ((0, r_pad), (0, 0)))
        offsets = jnp.pad(offsets, ((0, r_pad), (0, 0)))
    grid = ((r + r_pad) // br,)

    out = pl.pallas_call(
        functools.partial(
            _decode_kernel, stride=stride, anchor_w=anchor_w, anchor_h=anchor_h
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r + r_pad, d), jnp.float32),
        interpret=interpret,
    )(t, offsets)
    return out[:r] if r_pad else out


def make_offsets(grid_size: int) -> jnp.ndarray:
    """(G*G, 2) array of (gx, gy) cell coordinates, row-major over (gy, gx)."""
    gy, gx = jnp.meshgrid(
        jnp.arange(grid_size, dtype=jnp.float32),
        jnp.arange(grid_size, dtype=jnp.float32),
        indexing="ij",
    )
    return jnp.stack([gx.reshape(-1), gy.reshape(-1)], axis=-1)
