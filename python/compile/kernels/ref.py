"""Pure-jnp correctness oracles for every Pallas kernel.

pytest asserts kernel-vs-ref allclose (the CORE correctness signal), and
``train.py`` uses these for the build-time training loop — the math is
identical to the kernels, so trained weights transfer exactly to the
Pallas inference path that gets AOT-exported.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cloudscore import WHITE_THRESH
from .decode import WH_CLIP
from .matmul import LEAKY_SLOPE


def ref_fused_matmul(x, w, b, *, activation: str = "leaky_relu"):
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.reshape(1, -1)
    if activation == "leaky_relu":
        acc = jnp.where(acc >= 0.0, acc, LEAKY_SLOPE * acc)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return acc


def ref_decode_head(t, offsets, *, stride: float, anchor_w: float, anchor_h: float):
    xy = (jax.nn.sigmoid(t[:, 0:2]) + offsets) * stride
    wh = jnp.exp(jnp.clip(t[:, 2:4], -WH_CLIP, WH_CLIP)) * jnp.array(
        [anchor_w, anchor_h], dtype=jnp.float32
    )
    rest = jax.nn.sigmoid(t[:, 4:])
    return jnp.concatenate([xy, wh, rest], axis=-1)


def ref_cloud_score(x):
    lum = jnp.mean(x, axis=-1)
    mean_lum = jnp.mean(lum, axis=(1, 2))
    var_lum = jnp.mean((lum - mean_lum[:, None, None]) ** 2, axis=(1, 2))
    white = jnp.mean(
        (jnp.min(x, axis=-1) > WHITE_THRESH).astype(jnp.float32), axis=(1, 2)
    )
    return jnp.stack([mean_lum, var_lum, white], axis=-1)
