"""AOT export path: HLO text well-formed, weights round-trip, manifest."""

import jax
import numpy as np

from compile import aot
from compile import model as m


def test_export_detector_emits_parseable_hlo_text():
    params = m.init_params(jax.random.PRNGKey(0), "tiny")
    text = aot.export_detector(params, "tiny", batch=1)
    assert "ENTRY" in text
    assert "f32[1,64,64,3]" in text
    # decoded output shape appears as the root tuple element
    assert "f32[1,64,13]" in text


def test_export_cloudscore_emits_parseable_hlo_text():
    text = aot.export_cloudscore(batch=2)
    assert "ENTRY" in text
    assert "f32[2,64,64,3]" in text
    assert "f32[2,3]" in text


def test_weights_roundtrip(tmp_path):
    params = m.init_params(jax.random.PRNGKey(1), "tiny")
    p = tmp_path / "w.npz"
    h = aot.save_weights(p, params)
    assert len(h) == 16
    loaded = np.load(p)
    np.testing.assert_array_equal(loaded["w0"], np.asarray(params[0][0]))
    assert len(loaded.files) == 2 * len(params)


def test_baked_weights_are_constants():
    """Two different param sets must produce different HLO (weights baked,
    not parameters)."""
    p1 = m.init_params(jax.random.PRNGKey(1), "tiny")
    p2 = m.init_params(jax.random.PRNGKey(2), "tiny")
    t1 = aot.export_detector(p1, "tiny", batch=1)
    t2 = aot.export_detector(p2, "tiny", batch=1)
    assert t1 != t2
    # and the ENTRY computation takes exactly one parameter (the image
    # batch) — nested while-loop computations have their own numbering,
    # so scan only the ENTRY block.
    entry = t1[t1.index("ENTRY") :]
    assert entry.count("parameter(") == 1
