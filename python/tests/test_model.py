"""L2 model: shapes, pallas-vs-ref forward parity, decode sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m


@pytest.fixture(scope="module")
def tiny_params():
    return m.init_params(jax.random.PRNGKey(0), "tiny")


@pytest.fixture(scope="module")
def heavy_params():
    return m.init_params(jax.random.PRNGKey(1), "heavy")


def rand_imgs(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, size=(n, m.TILE, m.TILE, 3)).astype(np.float32))


def test_tiny_forward_shape(tiny_params):
    out = m.forward(tiny_params, rand_imgs(2), "tiny")
    assert out.shape == (2, m.GRID * m.GRID, m.HEAD_D)


def test_heavy_forward_shape(heavy_params):
    out = m.forward(heavy_params, rand_imgs(2), "heavy")
    assert out.shape == (2, m.GRID * m.GRID, m.HEAD_D)


@pytest.mark.parametrize("arch", ["tiny", "heavy"])
def test_pallas_matches_ref_forward(arch, tiny_params, heavy_params):
    params = tiny_params if arch == "tiny" else heavy_params
    x = rand_imgs(3, seed=42)
    ref = m.forward(params, x, arch, impl="ref")
    pal = m.forward(params, x, arch, impl="pallas")
    np.testing.assert_allclose(pal, ref, rtol=2e-4, atol=2e-4)


def test_im2col_matches_lax_conv(tiny_params):
    """im2col + matmul == lax.conv_general_dilated for stride 1 and 2."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)).astype(np.float32))
    wflat = jnp.asarray(rng.standard_normal((27, 4)).astype(np.float32))
    for stride in (1, 2):
        cols, (b, ho, wo) = m.im2col(x, stride)
        got = (cols @ wflat).reshape(b, ho, wo, 4)
        # (dy, dx, cin) patch order == HWIO kernel layout.  Note explicit
        # symmetric (1,1) padding: XLA's "SAME" pads (0,1) for even strides,
        # our im2col always pads (1,1) — both are valid convs; training and
        # inference share the im2col definition so it only has to be
        # self-consistent, which this test pins against lax.
        want = jax.lax.conv_general_dilated(
            x, wflat.reshape(3, 3, 3, 4), (stride, stride), [(1, 1), (1, 1)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decoded_boxes_in_plausible_range(tiny_params):
    out = np.asarray(m.forward(tiny_params, rand_imgs(1), "tiny"))[0]
    # centers within the tile (sigmoid+offset bounded by grid)
    assert (out[:, 0] >= 0).all() and (out[:, 0] <= m.TILE).all()
    assert (out[:, 1] >= 0).all() and (out[:, 1] <= m.TILE).all()
    assert (out[:, 4:] >= 0).all() and (out[:, 4:] <= 1).all()


def test_param_counts_ordered():
    tp = m.init_params(jax.random.PRNGKey(0), "tiny")
    hp = m.init_params(jax.random.PRNGKey(0), "heavy")
    assert m.param_count(hp) > 5 * m.param_count(tp)


def test_batch_invariance(tiny_params):
    """Row i of a batch equals the same tile run alone."""
    x = rand_imgs(4, seed=9)
    full = m.forward(tiny_params, x, "tiny")
    one = m.forward(tiny_params, x[2:3], "tiny")
    np.testing.assert_allclose(full[2], one[0], rtol=1e-4, atol=1e-5)
