"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes so block-edge padding paths (M not a multiple of
block_m, R not a multiple of block_r) are exercised, not just happy sizes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cloudscore as kc
from compile.kernels import decode as kd
from compile.kernels import matmul as km
from compile.kernels import ref as kr

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------- matmul
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 96),
    n=st.integers(1, 64),
    act=st.sampled_from(["leaky_relu", "none"]),
    block_m=st.sampled_from([8, 32, 128]),
)
def test_fused_matmul_matches_ref(m, k, n, act, block_m):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = km.fused_matmul(x, w, b, activation=act, block_m=block_m)
    want = kr.ref_fused_matmul(x, w, b, activation=act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_matmul_rejects_bad_activation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        km.fused_matmul(rand(rng, 4, 4), rand(rng, 4, 4), rand(rng, 4),
                        activation="gelu")


def test_fused_matmul_negative_slope_is_leaky():
    x = jnp.asarray([[-1.0]])
    w = jnp.asarray([[1.0]])
    b = jnp.asarray([0.0])
    out = km.fused_matmul(x, w, b, activation="leaky_relu")
    np.testing.assert_allclose(out, [[-km.LEAKY_SLOPE]], rtol=1e-6)


def test_vmem_footprint_within_tpu_budget():
    # The detector's worst conv shape must fit a 16 MiB VMEM with the
    # default BlockSpec (see DESIGN.md §Hardware-Adaptation).
    worst = km.vmem_footprint(km.DEFAULT_BLOCK_M, k=864, n=96)
    assert worst < 16 * 1024 * 1024


def test_mxu_utilization_monotone_in_m_alignment():
    aligned = km.mxu_utilization_estimate(256, 128, 128)
    ragged = km.mxu_utilization_estimate(129, 128, 128)
    assert aligned > ragged


# ---------------------------------------------------------------- decode
@given(
    rows=st.integers(1, 200),
    c=st.integers(1, 12),
    block_r=st.sampled_from([8, 64]),
)
def test_decode_matches_ref(rows, c, block_r):
    rng = np.random.default_rng(rows * 37 + c)
    t = rand(rng, rows, 5 + c)
    off = jnp.asarray(rng.uniform(0, 8, size=(rows, 2)).astype(np.float32))
    got = kd.decode_head(t, off, stride=8.0, anchor_w=16.0, anchor_h=12.0,
                         block_r=block_r)
    want = kr.ref_decode_head(t, off, stride=8.0, anchor_w=16.0, anchor_h=12.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_decode_clips_extreme_wh():
    t = jnp.full((1, 13), 100.0)
    off = jnp.zeros((1, 2))
    out = kd.decode_head(t, off, stride=8.0, anchor_w=16.0, anchor_h=16.0)
    assert np.isfinite(np.asarray(out)).all()
    assert float(out[0, 2]) <= 16.0 * np.exp(kd.WH_CLIP) + 1


def test_decode_scores_are_probabilities():
    rng = np.random.default_rng(3)
    t = rand(rng, 64, 13)
    off = kd.make_offsets(8)
    out = np.asarray(kd.decode_head(t, off, stride=8.0, anchor_w=16.0, anchor_h=16.0))
    assert (out[:, 4:] >= 0).all() and (out[:, 4:] <= 1).all()


def test_make_offsets_layout_row_major():
    off = np.asarray(kd.make_offsets(3))
    assert off.shape == (9, 2)
    # row-major over (gy, gx): second row is gx=1, gy=0
    np.testing.assert_array_equal(off[1], [1, 0])
    np.testing.assert_array_equal(off[3], [0, 1])


# ------------------------------------------------------------ cloudscore
@given(b=st.integers(1, 6), t=st.sampled_from([16, 32, 64]))
def test_cloudscore_matches_ref(b, t):
    rng = np.random.default_rng(b * 100 + t)
    x = jnp.asarray(rng.uniform(0, 1, size=(b, t, t, 3)).astype(np.float32))
    got = kc.cloud_score(x)
    want = kr.ref_cloud_score(x)
    assert got.shape == (b, 3)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cloudscore_white_image_is_fully_cloudy():
    x = jnp.ones((1, 32, 32, 3))
    out = np.asarray(kc.cloud_score(x))
    assert out[0, 2] == 1.0  # white_frac
    assert abs(out[0, 1]) < 1e-6  # zero variance


def test_cloudscore_dark_image_is_clear():
    x = jnp.zeros((1, 32, 32, 3)) + 0.1
    out = np.asarray(kc.cloud_score(x))
    assert out[0, 2] == 0.0
