"""Build-time training: loss decreases, targets well-formed, grads finite."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as d
from compile import model as m
from compile import train as t


def test_build_targets_layout():
    boxes = [[(12.0, 20.0, 8.0, 8.0, 3)]]  # cell gx=1, gy=2
    tgt = np.asarray(t.build_targets(boxes))
    assert tgt.shape == (1, m.GRID * m.GRID, m.HEAD_D)
    cell = 2 * m.GRID + 1
    assert tgt[0, cell, 4] == 1.0
    assert tgt[0, cell, 5 + 3] == 1.0
    np.testing.assert_allclose(tgt[0, cell, 0], 12.0 / 8.0 - 1.0)
    assert tgt[0].sum() == tgt[0, cell].sum()  # only one live cell


def test_build_targets_clamps_edge_boxes():
    boxes = [[(63.9, 63.9, 4.0, 4.0, 0)]]
    tgt = np.asarray(t.build_targets(boxes))
    assert tgt[0, m.GRID * m.GRID - 1, 4] == 1.0


def test_bce_matches_naive():
    logits = jnp.asarray([-3.0, 0.0, 2.0])
    labels = jnp.asarray([0.0, 1.0, 1.0])
    p = jax.nn.sigmoid(logits)
    naive = -(labels * jnp.log(p) + (1 - labels) * jnp.log(1 - p))
    np.testing.assert_allclose(t._bce(logits, labels), naive, rtol=1e-5)


def test_loss_finite_and_grads_flow():
    rng = np.random.default_rng(0)
    imgs, boxes = d.gen_training_batch(rng, 4)
    tgt = t.build_targets(boxes)
    params = m.init_params(jax.random.PRNGKey(0), "tiny")
    loss, grads = jax.value_and_grad(t.yolo_loss)(
        params, jnp.asarray(imgs), tgt, "tiny"
    )
    assert np.isfinite(float(loss))
    for gw, gb in grads:
        assert np.isfinite(np.asarray(gw)).all()
        assert np.abs(np.asarray(gw)).max() > 0


def test_short_training_reduces_loss():
    _, final_ema, history = t.train("tiny", 30, seed=3, batch=16, log_every=29,
                                    log=lambda *_: None)
    first = history[0][1]
    assert final_ema < first, f"loss did not decrease: {first} -> {final_ema}"


def test_adam_moves_params():
    params = m.init_params(jax.random.PRNGKey(0), "tiny")
    opt = t.adam_init(params)
    grads = [(jnp.ones_like(w), jnp.ones_like(b)) for w, b in params]
    new_params, _ = t.adam_update(params, grads, opt, lr=0.01)
    delta = float(jnp.abs(new_params[0][0] - params[0][0]).max())
    assert delta > 1e-4
