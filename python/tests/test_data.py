"""SynthDOTA python twin: determinism, ground-truth validity, and the
Fig-6 calibration (v1 ≈ 90% redundant, v2 ≈ 40%)."""

import numpy as np

from compile import data as d
from compile.kernels import cloudscore as kc


def test_tile_shape_and_range():
    rng = np.random.default_rng(0)
    img, boxes, cover = d.gen_tile(rng)
    assert img.shape == (d.TILE, d.TILE, 3)
    assert img.dtype == np.float32
    assert img.min() >= 0.0 and img.max() <= 1.0


def test_boxes_within_tile():
    rng = np.random.default_rng(1)
    for _ in range(50):
        _, boxes, _ = d.gen_tile(rng, objects_lam=2.5)
        for cx, cy, w, h, cls in boxes:
            assert 0 <= cx <= d.TILE and 0 <= cy <= d.TILE
            assert 0 < w <= d.TILE and 0 < h <= d.TILE
            assert 0 <= cls < d.CLASSES


def test_deterministic_given_seed():
    a, _, _ = d.gen_tile(np.random.default_rng(123))
    b, _, _ = d.gen_tile(np.random.default_rng(123))
    np.testing.assert_array_equal(a, b)


def test_objects_change_pixels():
    rng = np.random.default_rng(7)
    img = d.draw_background(rng)
    before = img.copy()
    d.draw_object(img, 0, rng)
    assert np.abs(img - before).max() > 0.1


def test_cloud_raises_white_fraction():
    rng = np.random.default_rng(11)
    img = d.draw_background(rng)
    clear_white = float(np.mean(np.min(img, axis=-1) > kc.WHITE_THRESH))
    cover = d.draw_cloud(img, np.random.default_rng(12), density=1.2)
    cloudy_white = float(np.mean(np.min(img, axis=-1) > kc.WHITE_THRESH))
    assert cloudy_white > clear_white
    assert cover > 0.0


def _redundancy_rate(version: str, n: int = 300) -> float:
    spec = d.VERSIONS[version]
    rng = np.random.default_rng(42)
    red = 0
    for _ in range(n):
        img, _, _ = d.gen_tile(
            rng,
            objects_lam=spec["objects_lam"],
            cloud_prob=spec["cloud_prob"],
            cloud_density=spec["cloud_density"],
        )
        white = float(np.mean(np.min(img, axis=-1) > kc.WHITE_THRESH))
        red += white > d.REDUNDANT_WHITE_FRAC
    return red / n


def test_v1_redundancy_near_90pct():
    rate = _redundancy_rate("v1")
    assert 0.75 <= rate <= 0.99, f"v1 redundancy {rate}"


def test_v2_redundancy_near_40pct():
    rate = _redundancy_rate("v2")
    assert 0.25 <= rate <= 0.55, f"v2 redundancy {rate}"


def test_training_batch_shapes():
    imgs, boxes = d.gen_training_batch(np.random.default_rng(0), 8)
    assert imgs.shape == (8, d.TILE, d.TILE, 3)
    assert len(boxes) == 8
