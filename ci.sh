#!/usr/bin/env bash
# Tier-1 verification + lint gate.  Run from anywhere; operates on rust/.
#
#   ./ci.sh          full gate: build, test, fmt --check, clippy -D warnings
#   ./ci.sh fast     build + test only (the tier-1 subset)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" == "fast" ]]; then
  exit 0
fi

echo "== lint: cargo fmt --check =="
cargo fmt --check

echo "== lint: cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "ci: all gates passed"
