#!/usr/bin/env bash
# Tier-1 verification + lint gate.  Run from anywhere; operates on rust/.
#
#   ./ci.sh          full gate: build, test, fmt --check, clippy -D warnings
#   ./ci.sh fast     build + test only (the tier-1 subset)
set -euo pipefail
cd "$(dirname "$0")/rust"

# Toolchain preflight: fail fast with one clear message instead of dying
# partway through the gate with a bare "command not found".
for tool in cargo rustc; do
  if ! command -v "$tool" >/dev/null 2>&1; then
    echo "ci: '$tool' not found on PATH — install a Rust toolchain (https://rustup.rs)" >&2
    echo "ci: no gates were run" >&2
    exit 1
  fi
done

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== determinism: merged flight-recorder trace across shard counts =="
# pins the trace stream byte-for-byte across shards × admission caps
cargo test -q --test trace_determinism

echo "== contact plane: multi-station scheduling invariants =="
# disjoint station-tagged plans, per-station byte attribution, and the
# single-station bit-identity of the layout refactor
cargo test -q --test station_scheduling

echo "== chaos: fault-plan determinism, ARQ reconciliation, crash recovery =="
# seeded fault plans are pure functions of (seed, sat); every rejected
# byte reconciles; zero-rate chaos is bit-identical to disabled
cargo test -q --test chaos_invariants

if [[ "${1:-}" == "fast" ]]; then
  exit 0
fi

echo "== lint: cargo fmt --check =="
cargo fmt --check

echo "== lint: cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== lint: cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== bench artifact: perf_engine -> BENCH_engine.json =="
if [[ -f artifacts/manifest.json ]]; then
  bench_log=$(mktemp)
  cargo bench --bench perf_engine | tee "$bench_log"
  # append, stamped per run, so the perf trajectory accumulates
  echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_engine.json
  grep '^{"bench"' "$bench_log" >> ../BENCH_engine.json || true
  rm -f "$bench_log"
  echo "BENCH_engine.json now holds $(wc -l < ../BENCH_engine.json) records"
else
  echo "skipping bench artifact: artifacts/ not built"
fi

echo "== bench artifact: perf_power -> BENCH_power.json =="
# artifact-free (pure mission-time integration): always recorded
bench_log=$(mktemp)
cargo bench --bench perf_power | tee "$bench_log"
echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_power.json
grep '^{"bench"' "$bench_log" >> ../BENCH_power.json || true
rm -f "$bench_log"
echo "BENCH_power.json now holds $(wc -l < ../BENCH_power.json) records"

echo "== bench artifact: perf_federated -> BENCH_federated.json =="
# artifact-free (scheduling + FedAvg, no inference runtime): always recorded
bench_log=$(mktemp)
cargo bench --bench perf_federated | tee "$bench_log"
echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_federated.json
grep '^{"bench"' "$bench_log" >> ../BENCH_federated.json || true
rm -f "$bench_log"
echo "BENCH_federated.json now holds $(wc -l < ../BENCH_federated.json) records"

echo "== bench artifact: perf_datapath -> BENCH_datapath.json =="
# artifact-free (pooled tiling + marshalling vs retained naive path, stub
# onboard loop): always recorded
bench_log=$(mktemp)
cargo bench --bench perf_datapath | tee "$bench_log"
echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_datapath.json
grep '^{"bench"' "$bench_log" >> ../BENCH_datapath.json || true
rm -f "$bench_log"
echo "BENCH_datapath.json now holds $(wc -l < ../BENCH_datapath.json) records"

echo "== bench artifact: perf_fleet -> BENCH_fleet.json =="
# artifact-free (sharded event scheduler over stub machines): always recorded
bench_log=$(mktemp)
cargo bench --bench perf_fleet | tee "$bench_log"
echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_fleet.json
grep '^{"bench"' "$bench_log" >> ../BENCH_fleet.json || true
rm -f "$bench_log"
echo "BENCH_fleet.json now holds $(wc -l < ../BENCH_fleet.json) records"

echo "== bench artifact: perf_observability -> BENCH_observability.json =="
# artifact-free (trace off vs on vs baseline on a stub fleet): always recorded
bench_log=$(mktemp)
cargo bench --bench perf_observability | tee "$bench_log"
echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_observability.json
grep '^{"bench"' "$bench_log" >> ../BENCH_observability.json || true
rm -f "$bench_log"
echo "BENCH_observability.json now holds $(wc -l < ../BENCH_observability.json) records"

echo "== bench artifact: perf_stations -> BENCH_stations.json =="
# artifact-free (orbital geometry + contact scheduling + ARQ drain over
# synthetic backlogs): always recorded; asserts multi-station yield beats
# the best single station
bench_log=$(mktemp)
cargo bench --bench perf_stations | tee "$bench_log"
echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_stations.json
grep '^{"bench"' "$bench_log" >> ../BENCH_stations.json || true
rm -f "$bench_log"
echo "BENCH_stations.json now holds $(wc -l < ../BENCH_stations.json) records"

echo "== bench artifact: perf_chaos -> BENCH_chaos.json =="
# artifact-free (fault-plan compilation + gated backlog drains at 0/1/10%
# fault rates over 1k satellites): always recorded; asserts the zero-rate
# lane is bitwise identical to the plain drain before timing anything
bench_log=$(mktemp)
cargo bench --bench perf_chaos | tee "$bench_log"
echo "{\"bench\":\"run\",\"commit\":\"$(git rev-parse --short HEAD 2>/dev/null || echo unknown)\",\"date\":\"$(date -u +%FT%TZ)\"}" >> ../BENCH_chaos.json
grep '^{"bench"' "$bench_log" >> ../BENCH_chaos.json || true
rm -f "$bench_log"
echo "BENCH_chaos.json now holds $(wc -l < ../BENCH_chaos.json) records"

echo "ci: all gates passed"
